//! The real execution engine: jobtracker + per-node tasktracker pools.
//!
//! Faithful to Hadoop 0.20's control flow at the granularity this repo
//! needs: FIFO scheduling with data-locality preference (a tasktracker
//! asking for work is handed a map task whose block lives on that node if
//! one is queued), bounded re-execution of failed attempts, speculative
//! duplicates of stragglers once the pending queue drains, a map-side
//! combiner, and a hash-partitioned sort-merge shuffle feeding the reduce
//! wave. Execution is genuinely parallel: one OS thread per (node, slot).
//!
//! Simulated *hardware* speed differences do not slow down the host
//! threads — they are the business of `sim`; this engine measures real
//! wall-clock and real scheduling behaviour (locality ratios, speculation
//! wins/waste, failure retries).
//!
//! **Node loss** (chaos-injected via [`FaultClock`]): a tasktracker whose
//! node stops heartbeating is *lost* — its running attempts are requeued
//! and, Hadoop-faithfully, so are its **completed** map tasks, because
//! map output lives on the node's local disk and the shuffle can no
//! longer fetch it. Nodes that keep failing attempts are blacklisted
//! (never the last live one). A job whose every tasktracker is gone
//! returns [`JobError::NodesLost`] instead of deadlocking, so multi-level
//! drivers can re-replicate blocks and resume from the last completed
//! level.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::chaos::FaultClock;
use crate::cluster::{ClusterConfig, NodeId};
use crate::data::split::{split_transactions, Split};
use crate::data::TransactionDb;
use crate::dfs::{BlockId, Dfs};
use crate::obs::TraceCtx;

use super::app::MapReduceApp;
use super::shuffle::{combine_local_in_place, group_by_key, partition_drain};

/// Failed fetches of one map's output tolerated before the shuffle
/// declares the output lost and re-executes the map (Hadoop's
/// fetch-failure → map re-execution threshold).
const SHUFFLE_FETCH_MAX_RETRIES: usize = 3;

/// Knobs of one job submission (Hadoop's `JobConf` analogue).
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Number of reduce tasks.
    pub n_reducers: usize,
    /// Run the app's combiner over each map task's output.
    pub enable_combiner: bool,
    /// Launch speculative duplicates of straggling map attempts.
    pub speculative: bool,
    /// A running task is a straggler once its runtime exceeds this multiple
    /// of the median completed map duration.
    pub speculation_slowdown: f64,
    /// Max attempts per task before the job aborts (Hadoop default 4).
    /// Attempts lost to a dead node do **not** count — only genuine
    /// attempt failures do (Hadoop's lost-tracker requeue semantics).
    pub max_attempts: usize,
    /// Blacklist a tasktracker after this many attempt failures on it
    /// (Hadoop's `mapred.max.tracker.failures`, default 4). The last
    /// live node is never blacklisted.
    pub node_blacklist_failures: usize,
    /// Deterministic failure injection, if any.
    pub failure: Option<FailureSpec>,
}

impl Default for JobConfig {
    fn default() -> Self {
        Self {
            n_reducers: 1,
            enable_combiner: true,
            speculative: true,
            speculation_slowdown: 1.5,
            max_attempts: 4,
            node_blacklist_failures: 4,
            failure: None,
        }
    }
}

/// Deterministic fault injection: attempt (task, n) fails iff a hash of
/// (seed, task, n) falls under the probability. Reproducible across runs.
#[derive(Debug, Clone, Copy)]
pub struct FailureSpec {
    pub map_fail_prob: f64,
    pub reduce_fail_prob: f64,
    pub seed: u64,
}

impl FailureSpec {
    fn fails(&self, prob: f64, task: usize, attempt: usize) -> bool {
        // splitmix-style avalanche over (seed, task, attempt)
        let mut z = self
            .seed
            .wrapping_add((task as u64) << 32)
            .wrapping_add(attempt as u64)
            .wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z as f64 / u64::MAX as f64) < prob
    }
}

/// Counters a run reports (Hadoop's job counters analogue).
#[derive(Debug, Clone, Default)]
pub struct JobStats {
    pub maps_total: usize,
    pub map_attempts: usize,
    pub map_failures: usize,
    pub speculative_launched: usize,
    pub speculative_wasted: usize,
    pub locality_local: usize,
    pub locality_remote: usize,
    pub shuffle_records: usize,
    pub reduces_total: usize,
    pub reduce_attempts: usize,
    pub reduce_failures: usize,
    pub output_records: usize,
    /// Tasktrackers lost (stopped heartbeating) during this job.
    pub lost_nodes: usize,
    /// Completed map tasks requeued because their output died with a
    /// lost node (map output lives on node-local disk).
    pub lost_maps_requeued: usize,
    /// Nodes blacklisted for repeated attempt failures.
    pub nodes_blacklisted: usize,
    /// Reducer fetches of map output that failed and were retried.
    pub shuffle_fetch_retries: usize,
    /// Maps re-executed after a reducer exhausted its fetch retries.
    pub maps_reexecuted: usize,
    pub map_secs: f64,
    pub reduce_secs: f64,
    pub total_secs: f64,
}

impl JobStats {
    /// Fraction of map attempts that read their split locally.
    pub fn locality_fraction(&self) -> f64 {
        let n = self.locality_local + self.locality_remote;
        if n == 0 {
            return 1.0;
        }
        self.locality_local as f64 / n as f64
    }
}

#[derive(Debug)]
pub enum JobError {
    MapTaskFailed {
        task: usize,
        attempts: usize,
        max: usize,
    },
    ReduceTaskFailed {
        task: usize,
        attempts: usize,
        max: usize,
    },
    BadPlacement { splits: usize, blocks: usize },
    NoReducers,
    /// Every tasktracker that could run the remaining tasks is gone —
    /// the job is stranded, not failed. Multi-level drivers recover by
    /// re-replicating blocks onto survivors and re-running the level.
    NodesLost { pending: usize, dead: usize },
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::MapTaskFailed { task, attempts, max } => {
                write!(f, "map task {task} failed {attempts} attempts (max {max})")
            }
            Self::ReduceTaskFailed { task, attempts, max } => {
                write!(f, "reduce task {task} failed {attempts} attempts (max {max})")
            }
            Self::BadPlacement { splits, blocks } => {
                write!(f, "splits/blocks length mismatch: {splits} vs {blocks}")
            }
            Self::NoReducers => write!(f, "n_reducers must be >= 1"),
            Self::NodesLost { pending, dead } => {
                write!(
                    f,
                    "job stranded: {pending} tasks unrunnable after losing {dead} node(s)"
                )
            }
        }
    }
}

impl std::error::Error for JobError {}

/// The job execution engine bound to a cluster + DFS placement.
pub struct JobRunner<'a> {
    pub cluster: &'a ClusterConfig,
    pub dfs: &'a Dfs,
    /// `blocks[i]` backs `splits[i]` (from `Dfs::write_splits`).
    pub blocks: &'a [BlockId],
    /// When set, every map/reduce task and the shuffle record spans
    /// (annotated with Hadoop-style job counters) under this context.
    /// `pub(crate)` so the coordinator can re-parent per level job.
    pub(crate) trace: Option<TraceCtx>,
    /// When set, the shared chaos clock: workers heartbeat against it
    /// (node death, slowdown) and the shuffle consults it per fetch.
    pub(crate) chaos: Option<Arc<FaultClock>>,
}

/// A completed map wave, ready for [`JobRunner::reduce_stage`]: the
/// per-task partitioned outputs plus the stats accumulated so far. Owning
/// this value is owning the intermediate data — it can be carried to
/// another thread so the reduce wave overlaps a successor job's map wave.
pub struct MapOutputs<K, V> {
    outputs: HashMap<usize, Vec<Vec<(K, V)>>>,
    stats: JobStats,
}

impl<K, V> MapOutputs<K, V> {
    /// Stats accumulated through the map wave (map counters populated,
    /// shuffle/reduce counters still zero).
    pub fn stats(&self) -> &JobStats {
        &self.stats
    }
}

/// Jobtracker state shared by all tasktracker threads.
struct MapPhase<K, V> {
    pending: Vec<usize>,
    /// task -> live attempts as (node running it, start instant)
    running: HashMap<usize, Vec<(NodeId, Instant)>>,
    attempts_started: HashMap<usize, usize>,
    /// task -> genuine attempt failures (lost-node requeues excluded) —
    /// this, not the attempt number, is what `max_attempts` bounds.
    failed_attempts: HashMap<usize, usize>,
    completed: HashSet<usize>,
    /// task -> node whose local disk holds the completed map output.
    completed_on: HashMap<usize, NodeId>,
    completed_durations: Vec<f64>,
    /// node -> attempt failures charged to it (blacklisting input).
    node_failures: HashMap<NodeId, usize>,
    blacklisted: HashSet<NodeId>,
    /// Nodes whose loss this jobtracker has already processed.
    lost_nodes: HashSet<NodeId>,
    outputs: HashMap<usize, Vec<Vec<(K, V)>>>,
    stats: JobStats,
    abort: Option<JobError>,
}

impl<K, V> MapPhase<K, V> {
    /// Lost-tasktracker cleanup (Hadoop's heartbeat-timeout path): drop
    /// the node's running attempts, requeue its completed map tasks —
    /// their output lived on its local disk — and requeue anything left
    /// with no live attempt. Idempotent per node.
    fn lose_node(&mut self, node: NodeId) {
        if !self.lost_nodes.insert(node) {
            return;
        }
        self.stats.lost_nodes += 1;
        let mut stranded: Vec<usize> = Vec::new();
        for (&task, starts) in self.running.iter_mut() {
            let before = starts.len();
            starts.retain(|&(n, _)| n != node);
            if starts.len() < before && starts.is_empty() {
                stranded.push(task);
            }
        }
        self.running.retain(|_, starts| !starts.is_empty());
        for task in stranded {
            if !self.completed.contains(&task) && !self.pending.contains(&task) {
                self.pending.push(task);
            }
        }
        let lost_outputs: Vec<usize> = self
            .completed_on
            .iter()
            .filter(|&(_, &n)| n == node)
            .map(|(&t, _)| t)
            .collect();
        for task in lost_outputs {
            self.completed_on.remove(&task);
            self.completed.remove(&task);
            self.outputs.remove(&task);
            self.stats.lost_maps_requeued += 1;
            if !self.pending.contains(&task) && !self.running.contains_key(&task) {
                self.pending.push(task);
            }
        }
    }
}

impl<'a> JobRunner<'a> {
    pub fn new(cluster: &'a ClusterConfig, dfs: &'a Dfs, blocks: &'a [BlockId]) -> Self {
        Self { cluster, dfs, blocks, trace: None, chaos: None }
    }

    /// Attach (or detach) a tracing context; task-level spans become
    /// children of it. `None` — the default — is the zero-cost off path.
    pub fn with_trace(mut self, trace: Option<TraceCtx>) -> Self {
        self.trace = trace;
        self
    }

    /// Attach (or detach) the shared fault clock. `None` — the default —
    /// runs fault-free with zero overhead on the hot path.
    pub fn with_chaos(mut self, chaos: Option<Arc<FaultClock>>) -> Self {
        self.chaos = chaos;
        self
    }

    /// Run one job to completion. Output is key-sorted and deterministic.
    /// Composes [`map_stage`](Self::map_stage) + [`reduce_stage`](Self::reduce_stage).
    pub fn run<A: MapReduceApp>(
        &self,
        app: &A,
        db: &TransactionDb,
        splits: &[Split],
        cfg: &JobConfig,
    ) -> Result<(Vec<(A::K, A::V)>, JobStats), JobError> {
        let outputs = self.map_stage(app, db, splits, cfg)?;
        self.reduce_stage(app, db, splits, outputs, cfg)
    }

    /// Run just the map wave of a job: validate, schedule the map tasks
    /// over the tasktracker pool, and hand back the partitioned map
    /// outputs. [`reduce_stage`](Self::reduce_stage) completes the job.
    ///
    /// Splitting the two waves is what lets the pipelined coordinator
    /// overlap a successor job's map wave with its predecessor's reduce
    /// wave: the predecessor's `reduce_stage` runs on a spare lane while
    /// the slots the map wave freed pick up the next job's map tasks.
    pub fn map_stage<A: MapReduceApp>(
        &self,
        app: &A,
        db: &TransactionDb,
        splits: &[Split],
        cfg: &JobConfig,
    ) -> Result<MapOutputs<A::K, A::V>, JobError> {
        if cfg.n_reducers == 0 {
            return Err(JobError::NoReducers);
        }
        if splits.len() != self.blocks.len() {
            return Err(JobError::BadPlacement {
                splits: splits.len(),
                blocks: self.blocks.len(),
            });
        }
        let started = Instant::now();
        let (outputs, mut stats) = self.map_phase(app, db, splits, cfg)?;
        stats.map_secs = started.elapsed().as_secs_f64();
        Ok(MapOutputs { outputs, stats })
    }

    /// Shuffle + reduce wave over a completed map stage. Output is
    /// key-sorted and deterministic regardless of what else is running on
    /// the cluster (the shuffle pulls partitions in task order).
    ///
    /// `db` and `splits` are the map stage's inputs: a fetch of some
    /// map's output that keeps failing past the retry cap is resolved —
    /// Hadoop-faithfully — by re-executing that map, which needs them.
    pub fn reduce_stage<A: MapReduceApp>(
        &self,
        app: &A,
        db: &TransactionDb,
        splits: &[Split],
        map_outputs: MapOutputs<A::K, A::V>,
        cfg: &JobConfig,
    ) -> Result<(Vec<(A::K, A::V)>, JobStats), JobError> {
        let MapOutputs { mut outputs, mut stats } = map_outputs;

        // Shuffle: reducer r pulls partition r of every map output, in
        // task order (determinism). Each reducer's input buffer is sized
        // up front from the per-partition record totals, and the parked
        // map outputs are moved in, never cloned.
        let t1 = Instant::now();
        let shuffle_span = self.trace.as_ref().map(|ctx| ctx.span("mr", "shuffle"));
        let mut task_ids: Vec<usize> = outputs.keys().copied().collect();
        task_ids.sort_unstable();
        let mut part_sizes = vec![0usize; cfg.n_reducers];
        for parts in outputs.values() {
            for (r, part) in parts.iter().enumerate() {
                part_sizes[r] += part.len();
            }
        }
        let mut reduce_inputs: Vec<Vec<(A::K, A::V)>> = part_sizes
            .iter()
            .map(|&n| Vec::with_capacity(n))
            .collect();
        for tid in task_ids {
            let mut parts = outputs.remove(&tid).expect("task id came from the key set");
            if let Some(clock) = &self.chaos {
                // Fetch-failure handling, Hadoop semantics: retry with
                // capped exponential backoff; past the cap, declare the
                // map output lost and re-execute the map (deterministic
                // ⇒ byte-identical replacement output).
                let mut backoff = Duration::from_millis(1);
                let mut failures = 0usize;
                while clock.take_shuffle_fault(tid) {
                    failures += 1;
                    stats.shuffle_fetch_retries += 1;
                    if failures >= SHUFFLE_FETCH_MAX_RETRIES {
                        parts = self.execute_map(app, db, &splits[tid], cfg);
                        stats.maps_reexecuted += 1;
                        break;
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(8));
                }
            }
            for (r, part) in parts.into_iter().enumerate() {
                stats.shuffle_records += part.len();
                reduce_inputs[r].extend(part);
            }
        }
        if let Some(mut s) = shuffle_span {
            s.add("shuffle_records", stats.shuffle_records as f64);
            s.add(
                "shuffle_bytes",
                (stats.shuffle_records * app.record_bytes_hint()) as f64,
            );
        }

        let output = self.reduce_phase(app, reduce_inputs, cfg, &mut stats)?;
        stats.reduce_secs = t1.elapsed().as_secs_f64();
        stats.output_records = output.len();
        // Sum of the stages' own elapsed times: a pipelined coordinator may
        // park the map outputs while a predecessor's reduce lane drains,
        // and that wait is scheduling, not this job's work.
        stats.total_secs = stats.map_secs + stats.reduce_secs;
        Ok((output, stats))
    }

    /// One clean map execution of `split` (no failure injection, no
    /// scheduling): the shuffle's map re-execution path. The app's map
    /// and combiner are deterministic, so the partitions are
    /// byte-identical to the output the lost node held.
    fn execute_map<A: MapReduceApp>(
        &self,
        app: &A,
        db: &TransactionDb,
        split: &Split,
        cfg: &JobConfig,
    ) -> Vec<Vec<(A::K, A::V)>> {
        let mut records: Vec<(A::K, A::V)> = Vec::new();
        let mut scratch: Vec<A::V> = Vec::new();
        let input = split_transactions(db, split);
        app.map(split, input, &mut |k, v| records.push((k, v)));
        if cfg.enable_combiner {
            combine_local_in_place(&mut records, |k, vs| app.combine(k, vs), &mut scratch);
        }
        partition_drain(&mut records, cfg.n_reducers)
    }

    /// The map wave: tasktracker threads pull tasks with locality
    /// preference; stragglers get speculative duplicates.
    #[allow(clippy::type_complexity)]
    fn map_phase<A: MapReduceApp>(
        &self,
        app: &A,
        db: &TransactionDb,
        splits: &[Split],
        cfg: &JobConfig,
    ) -> Result<(HashMap<usize, Vec<Vec<(A::K, A::V)>>>, JobStats), JobError> {
        let n_tasks = splits.len();
        let state = Mutex::new(MapPhase::<A::K, A::V> {
            pending: (0..n_tasks).collect(),
            running: HashMap::new(),
            attempts_started: HashMap::new(),
            failed_attempts: HashMap::new(),
            completed: HashSet::new(),
            completed_on: HashMap::new(),
            completed_durations: Vec::with_capacity(n_tasks),
            node_failures: HashMap::new(),
            blacklisted: HashSet::new(),
            lost_nodes: HashSet::new(),
            // One entry per map task — sized once, never rehashed.
            outputs: HashMap::with_capacity(n_tasks),
            stats: JobStats {
                maps_total: n_tasks,
                reduces_total: cfg.n_reducers,
                ..Default::default()
            },
            abort: None,
        });
        let cv = Condvar::new();

        std::thread::scope(|scope| {
            for (node, profile) in self.cluster.nodes.iter().enumerate() {
                for _slot in 0..profile.slots {
                    let state = &state;
                    let cv = &cv;
                    scope.spawn(move || {
                        self.map_worker(app, db, splits, cfg, node, state, cv);
                    });
                }
            }
        });

        let mut st = state.into_inner().unwrap();
        if let Some(err) = st.abort.take() {
            return Err(err);
        }
        if st.completed.len() != st.stats.maps_total {
            // Every worker exited (dead or blacklisted trackers stop
            // pulling) with tasks still unfinished: the job is stranded.
            return Err(JobError::NodesLost {
                pending: st.stats.maps_total - st.completed.len(),
                dead: st.lost_nodes.len(),
            });
        }
        let outputs = std::mem::take(&mut st.outputs);
        Ok((outputs, st.stats.clone()))
    }

    fn map_worker<A: MapReduceApp>(
        &self,
        app: &A,
        db: &TransactionDb,
        splits: &[Split],
        cfg: &JobConfig,
        node: NodeId,
        state: &Mutex<MapPhase<A::K, A::V>>,
        cv: &Condvar,
    ) {
        // Per-slot scratch reused across every split this worker runs:
        // the map-output buffer and the combiner's value scratch keep
        // their capacity between attempts, so steady-state map execution
        // allocates only the partition buckets it hands to the shuffle.
        let mut records: Vec<(A::K, A::V)> = Vec::new();
        let mut combine_scratch: Vec<A::V> = Vec::new();
        loop {
            // --- pick a task under the lock ---
            let picked: Option<(usize, usize, bool)> = {
                let mut st = state.lock().unwrap();
                loop {
                    // 0. heartbeat: a dead tasktracker takes its running
                    // attempts and node-local map outputs with it; a
                    // blacklisted one just stops pulling work.
                    if let Some(clock) = &self.chaos {
                        if clock.is_dead(node) {
                            st.lose_node(node);
                            cv.notify_all();
                            return;
                        }
                    }
                    if st.blacklisted.contains(&node) {
                        cv.notify_all();
                        return;
                    }
                    if st.abort.is_some() || st.completed.len() == st.stats.maps_total {
                        cv.notify_all();
                        return;
                    }
                    // 1. locality-preferred FIFO from the pending queue
                    if !st.pending.is_empty() {
                        let pos = st
                            .pending
                            .iter()
                            .position(|&t| self.dfs.is_local(self.blocks[t], node))
                            .unwrap_or(0);
                        let task = st.pending.remove(pos);
                        let local = self.dfs.is_local(self.blocks[task], node);
                        if local {
                            st.stats.locality_local += 1;
                        } else {
                            st.stats.locality_remote += 1;
                        }
                        let attempt = *st
                            .attempts_started
                            .entry(task)
                            .and_modify(|a| *a += 1)
                            .or_insert(1);
                        st.running.entry(task).or_default().push((node, Instant::now()));
                        st.stats.map_attempts += 1;
                        break Some((task, attempt, false));
                    }
                    // 2. speculation: duplicate the slowest straggler
                    if cfg.speculative && !st.completed_durations.is_empty() {
                        let mut ds = st.completed_durations.clone();
                        ds.sort_by(f64::total_cmp);
                        let median = ds[ds.len() / 2];
                        let threshold = median * cfg.speculation_slowdown;
                        let straggler = st
                            .running
                            .iter()
                            .filter(|(t, starts)| {
                                !st.completed.contains(t)
                                    && starts.len() == 1 // not yet duplicated
                                    && starts[0].1.elapsed().as_secs_f64() > threshold
                            })
                            .map(|(&t, _)| t)
                            .next();
                        if let Some(task) = straggler {
                            let attempt = *st
                                .attempts_started
                                .entry(task)
                                .and_modify(|a| *a += 1)
                                .or_insert(1);
                            st.running.get_mut(&task).unwrap().push((node, Instant::now()));
                            st.stats.map_attempts += 1;
                            st.stats.speculative_launched += 1;
                            break Some((task, attempt, true));
                        }
                    }
                    // 3. nothing to do yet: wait for completions/failures
                    let (guard, _timeout) = cv
                        .wait_timeout(st, std::time::Duration::from_millis(2))
                        .unwrap();
                    st = guard;
                }
            };
            let Some((task, attempt, speculative)) = picked else {
                return;
            };

            // --- execute the attempt outside the lock ---
            let mut span = self.trace.as_ref().map(|ctx| {
                let mut s = ctx.span("mr", format!("map.task.{task}"));
                s.add("task", task as f64);
                s.add("attempt", attempt as f64);
                s.add("speculative", if speculative { 1.0 } else { 0.0 });
                s.add("candidates", app.n_candidates() as f64);
                s.add("node", node as f64);
                s
            });
            let started = Instant::now();
            let failed = cfg
                .failure
                .map(|f| f.fails(f.map_fail_prob, task, attempt))
                .unwrap_or(false);
            let result = if failed {
                if let Some(s) = span.as_mut() {
                    s.add("failed", 1.0);
                }
                None
            } else {
                records.clear();
                let input = split_transactions(db, &splits[task]);
                app.map(&splits[task], input, &mut |k, v| records.push((k, v)));
                let map_output_records = records.len();
                if cfg.enable_combiner {
                    combine_local_in_place(
                        &mut records,
                        |k, vs| app.combine(k, vs),
                        &mut combine_scratch,
                    );
                }
                if let Some(s) = span.as_mut() {
                    s.add("records_read", input.len() as f64);
                    s.add("map_output_records", map_output_records as f64);
                    s.add("combine_output_records", records.len() as f64);
                    s.add(
                        "combiner_ratio",
                        if map_output_records > 0 {
                            records.len() as f64 / map_output_records as f64
                        } else {
                            1.0
                        },
                    );
                    s.add(
                        "shuffle_bytes",
                        (records.len() * app.record_bytes_hint()) as f64,
                    );
                }
                Some(partition_drain(&mut records, cfg.n_reducers))
            };
            // A degraded node does the same work, slower (bounded so
            // chaos runs stay fast; the *scheduling* consequences —
            // speculation, blacklist pressure — are what matter). The
            // sleep happens while the attempt's span is still open, so a
            // `slow:` fault shows up in the task's traced duration and
            // the analyzer can attribute the straggler to this node.
            if let Some(clock) = &self.chaos {
                let factor = clock.slow_factor(node);
                if factor > 1.0 {
                    let extra = started.elapsed().mul_f64(factor - 1.0);
                    std::thread::sleep(extra.min(Duration::from_millis(50)));
                }
            }
            // Record the span before contending for the report lock.
            drop(span);

            // --- report under the lock ---
            let mut st = state.lock().unwrap();
            if let Some(clock) = &self.chaos {
                if clock.is_dead(node) {
                    // the node died while this attempt ran: its output
                    // never reaches the jobtracker
                    st.lose_node(node);
                    cv.notify_all();
                    return;
                }
            }
            match result {
                Some(partitions) => {
                    if st.completed.insert(task) {
                        st.completed_on.insert(task, node);
                        st.completed_durations
                            .push(started.elapsed().as_secs_f64());
                        st.outputs.insert(task, partitions);
                        if let Some(clock) = &self.chaos {
                            clock.on_map_completion();
                        }
                    } else if speculative || attempt > 1 {
                        st.stats.speculative_wasted += 1;
                    }
                    st.running.remove(&task);
                }
                None => {
                    st.stats.map_failures += 1;
                    // remove this attempt's start record
                    if let Some(starts) = st.running.get_mut(&task) {
                        if let Some(pos) = starts.iter().position(|&(n, _)| n == node) {
                            starts.remove(pos);
                        }
                        if starts.is_empty() {
                            st.running.remove(&task);
                        }
                    }
                    // charge the node; blacklist repeat offenders, but
                    // never the last node still pulling work
                    let node_fails = {
                        let e = st.node_failures.entry(node).or_insert(0);
                        *e += 1;
                        *e
                    };
                    if node_fails >= cfg.node_blacklist_failures {
                        let live = self
                            .cluster
                            .n_nodes()
                            .saturating_sub(st.lost_nodes.len())
                            .saturating_sub(st.blacklisted.len());
                        if live > 1 && st.blacklisted.insert(node) {
                            st.stats.nodes_blacklisted += 1;
                            if let Some(clock) = &self.chaos {
                                clock.note_blacklisted(node);
                            }
                        }
                    }
                    let failed = {
                        let e = st.failed_attempts.entry(task).or_insert(0);
                        *e += 1;
                        *e
                    };
                    if st.completed.contains(&task) {
                        // a twin already finished; nothing to do
                    } else if failed >= cfg.max_attempts {
                        st.abort = Some(JobError::MapTaskFailed {
                            task,
                            attempts: failed,
                            max: cfg.max_attempts,
                        });
                    } else if !st.pending.contains(&task)
                        && !st.running.contains_key(&task)
                    {
                        st.pending.push(task); // re-queue for retry
                    }
                }
            }
            cv.notify_all();
        }
    }

    /// The reduce wave: `n_reducers` tasks over the worker pool (reducers
    /// have no locality — Hadoop pulls map output over the network anyway).
    fn reduce_phase<A: MapReduceApp>(
        &self,
        app: &A,
        reduce_inputs: Vec<Vec<(A::K, A::V)>>,
        cfg: &JobConfig,
        stats: &mut JobStats,
    ) -> Result<Vec<(A::K, A::V)>, JobError> {
        struct RedState<K, V> {
            pending: Vec<usize>,
            attempts: HashMap<usize, usize>,
            done: HashMap<usize, Vec<(K, V)>>,
            failures: usize,
            attempts_total: usize,
            abort: Option<JobError>,
        }
        let n = reduce_inputs.len();
        let state = Mutex::new(RedState::<A::K, A::V> {
            pending: (0..n).collect(),
            attempts: HashMap::new(),
            done: HashMap::with_capacity(n),
            failures: 0,
            attempts_total: 0,
            abort: None,
        });
        // Each reduce task consumes its input by move (a successful
        // attempt takes it; failed attempts never touch it), so the
        // shuffle's buffers are the ones the sort-merge runs on — no
        // per-task clone of the whole partition.
        let inputs = reduce_inputs
            .into_iter()
            .map(|v| Mutex::new(Some(v)))
            .collect::<Vec<_>>();
        let inputs = &inputs;

        std::thread::scope(|scope| {
            for (node, profile) in self.cluster.nodes.iter().enumerate() {
                for _slot in 0..profile.slots {
                    let state = &state;
                    scope.spawn(move || loop {
                        // heartbeat: a dead node's reducers stop pulling;
                        // unclaimed partitions fail over to survivors
                        // (an in-flight attempt finishes — the input was
                        // already fetched, Hadoop's heartbeat lag).
                        if let Some(clock) = &self.chaos {
                            if clock.is_dead(node) {
                                return;
                            }
                        }
                        let picked = {
                            let mut st = state.lock().unwrap();
                            if st.abort.is_some() || st.done.len() == n {
                                return;
                            }
                            match st.pending.pop() {
                                Some(t) => {
                                    let a = *st.attempts.entry(t).and_modify(|x| *x += 1).or_insert(1);
                                    st.attempts_total += 1;
                                    Some((t, a))
                                }
                                None => None,
                            }
                        };
                        let Some((task, attempt)) = picked else {
                            std::thread::sleep(std::time::Duration::from_micros(200));
                            continue;
                        };
                        let failed = cfg
                            .failure
                            .map(|f| f.fails(f.reduce_fail_prob, task + 1_000_000, attempt))
                            .unwrap_or(false);
                        if failed {
                            let mut st = state.lock().unwrap();
                            st.failures += 1;
                            if attempt >= cfg.max_attempts {
                                st.abort = Some(JobError::ReduceTaskFailed {
                                    task,
                                    attempts: attempt,
                                    max: cfg.max_attempts,
                                });
                            } else {
                                st.pending.push(task);
                            }
                            continue;
                        }
                        // Invariant: a task is popped from `pending` at
                        // most once and failure is decided before the
                        // take, so the input is always present here. If
                        // reduce-side speculation is ever added, twin
                        // attempts must learn to share — loudly, not by
                        // silently dropping the task.
                        let input = inputs[task]
                            .lock()
                            .unwrap()
                            .take()
                            .expect("reduce input consumed twice");
                        let mut span = self.trace.as_ref().map(|ctx| {
                            let mut s = ctx.span("mr", format!("reduce.task.{task}"));
                            s.add("task", task as f64);
                            s.add("node", node as f64);
                            s.add("attempt", attempt as f64);
                            s.add("reduce_input_records", input.len() as f64);
                            s
                        });
                        let mut out: Vec<(A::K, A::V)> = Vec::new();
                        for (k, vs) in group_by_key(input) {
                            if let Some(v) = app.reduce(&k, &vs) {
                                out.push((k, v));
                            }
                        }
                        if let Some(s) = span.as_mut() {
                            s.add("output_records", out.len() as f64);
                        }
                        drop(span);
                        let mut st = state.lock().unwrap();
                        st.done.insert(task, out);
                    });
                }
            }
        });

        let mut st = state.into_inner().unwrap();
        if let Some(err) = st.abort.take() {
            return Err(err);
        }
        if st.done.len() != n {
            return Err(JobError::NodesLost {
                pending: n - st.done.len(),
                dead: self.chaos.as_ref().map(|c| c.dead_nodes().len()).unwrap_or(0),
            });
        }
        stats.reduce_attempts = st.attempts_total;
        stats.reduce_failures = st.failures;
        // Deterministic final order: concat partitions by id, sort by key.
        let mut output = Vec::new();
        for r in 0..n {
            output.extend(st.done.remove(&r).unwrap());
        }
        output.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::quest::{QuestGenerator, QuestParams};
    use crate::data::split::plan_splits;
    use crate::mapreduce::app::ItemCount;

    fn fixture(n_nodes: usize, n_tx: usize) -> (ClusterConfig, TransactionDb, Vec<Split>) {
        let db = QuestGenerator::new(QuestParams::t10_i4(n_tx)).generate();
        let splits = plan_splits(&db, (n_tx / (n_nodes * 2)).max(1));
        (ClusterConfig::fhssc(n_nodes), db, splits)
    }

    fn ground_truth(db: &TransactionDb) -> Vec<(u32, u64)> {
        let mut counts: HashMap<u32, u64> = HashMap::new();
        for t in &db.transactions {
            for &i in &t.items {
                *counts.entry(i).or_insert(0) += 1;
            }
        }
        let mut v: Vec<_> = counts.into_iter().collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn item_count_end_to_end_matches_ground_truth() {
        let (cluster, db, splits) = fixture(3, 1000);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let cfg = JobConfig { n_reducers: 4, ..Default::default() };
        let (out, stats) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
        assert_eq!(out, ground_truth(&db));
        assert_eq!(stats.maps_total, splits.len());
        assert!(stats.map_attempts >= splits.len());
        assert_eq!(stats.output_records, out.len());
        assert!(stats.total_secs > 0.0);
    }

    #[test]
    fn staged_run_equals_one_shot_run() {
        let (cluster, db, splits) = fixture(3, 900);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let cfg = JobConfig { n_reducers: 3, ..Default::default() };
        let (one_shot, s1) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
        let mo = runner.map_stage(&ItemCount, &db, &splits, &cfg).unwrap();
        assert_eq!(mo.stats().maps_total, splits.len());
        assert_eq!(mo.stats().shuffle_records, 0, "shuffle not yet pulled");
        let (staged, s2) = runner.reduce_stage(&ItemCount, &db, &splits, mo, &cfg).unwrap();
        assert_eq!(one_shot, staged);
        assert_eq!(s1.shuffle_records, s2.shuffle_records);
        assert_eq!(s1.output_records, s2.output_records);
    }

    #[test]
    fn successor_map_wave_overlaps_predecessor_reduce() {
        // Two jobs staged by hand: job B's map wave runs while job A's
        // reduce wave is still in flight on another lane. Both must still
        // produce the exact ground truth with identical shuffle volumes.
        let (cluster, db, splits) = fixture(3, 1200);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let cfg = JobConfig { n_reducers: 4, ..Default::default() };
        let truth = ground_truth(&db);

        let mo_a = runner.map_stage(&ItemCount, &db, &splits, &cfg).unwrap();
        let ((out_a, stats_a), (out_b, stats_b)) = std::thread::scope(|s| {
            let reduce_a =
                s.spawn(|| runner.reduce_stage(&ItemCount, &db, &splits, mo_a, &cfg).unwrap());
            let mo_b = runner.map_stage(&ItemCount, &db, &splits, &cfg).unwrap();
            let b = runner.reduce_stage(&ItemCount, &db, &splits, mo_b, &cfg).unwrap();
            (reduce_a.join().unwrap(), b)
        });
        assert_eq!(out_a, truth);
        assert_eq!(out_b, truth);
        assert_eq!(stats_a.shuffle_records, stats_b.shuffle_records);
        assert_eq!(stats_a.maps_total, stats_b.maps_total);
    }

    #[test]
    fn deterministic_output_across_runs_and_reducer_counts() {
        let (cluster, db, splits) = fixture(2, 600);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let mut results = Vec::new();
        for n_reducers in [1, 2, 7] {
            let cfg = JobConfig { n_reducers, ..Default::default() };
            let (out, _) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
            results.push(out);
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
    }

    #[test]
    fn combiner_does_not_change_results_but_cuts_shuffle() {
        let (cluster, db, splits) = fixture(2, 800);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let on = JobConfig { enable_combiner: true, n_reducers: 2, ..Default::default() };
        let off = JobConfig { enable_combiner: false, n_reducers: 2, ..Default::default() };
        let (a, sa) = runner.run(&ItemCount, &db, &splits, &on).unwrap();
        let (b, sb) = runner.run(&ItemCount, &db, &splits, &off).unwrap();
        assert_eq!(a, b);
        assert!(
            sa.shuffle_records * 2 < sb.shuffle_records,
            "combiner should collapse shuffle: {} vs {}",
            sa.shuffle_records,
            sb.shuffle_records
        );
    }

    #[test]
    fn locality_mostly_local_on_replicated_cluster() {
        let (cluster, db, splits) = fixture(3, 2000);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let (_, stats) = runner
            .run(&ItemCount, &db, &splits, &JobConfig::default())
            .unwrap();
        // replication 3 on 3 nodes -> every block local everywhere.
        assert_eq!(stats.locality_fraction(), 1.0);
    }

    #[test]
    fn failure_injection_retries_and_recovers() {
        let (cluster, db, splits) = fixture(2, 500);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let cfg = JobConfig {
            failure: Some(FailureSpec {
                map_fail_prob: 0.3,
                reduce_fail_prob: 0.2,
                seed: 7,
            }),
            speculative: false,
            ..Default::default()
        };
        let (out, stats) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
        assert_eq!(out, ground_truth(&db));
        assert!(stats.map_failures > 0, "expected injected failures");
        assert!(stats.map_attempts > stats.maps_total);
    }

    #[test]
    fn unrecoverable_failure_aborts_with_error() {
        let (cluster, db, splits) = fixture(2, 200);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let cfg = JobConfig {
            failure: Some(FailureSpec {
                map_fail_prob: 1.0,
                reduce_fail_prob: 0.0,
                seed: 1,
            }),
            max_attempts: 3,
            ..Default::default()
        };
        match runner.run(&ItemCount, &db, &splits, &cfg) {
            Err(JobError::MapTaskFailed { attempts: 3, max: 3, .. }) => {}
            other => panic!("expected MapTaskFailed, got {other:?}"),
        }
    }

    #[test]
    fn reduce_failures_exhaust_and_abort() {
        let (cluster, db, splits) = fixture(2, 200);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let cfg = JobConfig {
            failure: Some(FailureSpec {
                map_fail_prob: 0.0,
                reduce_fail_prob: 1.0,
                seed: 2,
            }),
            max_attempts: 2,
            ..Default::default()
        };
        assert!(matches!(
            runner.run(&ItemCount, &db, &splits, &cfg),
            Err(JobError::ReduceTaskFailed { .. })
        ));
    }

    #[test]
    fn killed_node_requeues_its_completed_maps_and_job_recovers() {
        use crate::chaos::{FaultClock, FaultPlan};
        let (cluster, db, splits) = fixture(3, 1200);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let truth = ground_truth(&db);
        // node 1 dies after its tracker has had a chance to complete
        // maps: those outputs are gone and must be re-executed elsewhere
        let clock = Arc::new(FaultClock::new(FaultPlan::parse("kill:1@maps:2").unwrap()));
        let runner = JobRunner::new(&cluster, &dfs, &blocks).with_chaos(Some(Arc::clone(&clock)));
        let cfg = JobConfig { n_reducers: 2, ..Default::default() };
        let (out, stats) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
        assert_eq!(out, truth, "recovery must not change the answer");
        assert_eq!(stats.lost_nodes, 1);
        assert!(clock.is_dead(1));
    }

    #[test]
    fn losing_every_node_strands_the_job_with_a_typed_error() {
        use crate::chaos::{FaultClock, FaultPlan};
        let (cluster, db, splits) = fixture(2, 400);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let clock = Arc::new(FaultClock::new(FaultPlan::parse("kill:0@now;kill:1@now").unwrap()));
        let runner = JobRunner::new(&cluster, &dfs, &blocks).with_chaos(Some(clock));
        match runner.run(&ItemCount, &db, &splits, &JobConfig::default()) {
            Err(JobError::NodesLost { pending, dead }) => {
                assert_eq!(pending, splits.len());
                assert_eq!(dead, 2);
            }
            other => panic!("expected NodesLost, got {other:?}"),
        }
    }

    #[test]
    fn repeated_failures_blacklist_a_node_but_never_the_last_one() {
        let (cluster, db, splits) = fixture(2, 800);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let cfg = JobConfig {
            failure: Some(FailureSpec { map_fail_prob: 0.5, reduce_fail_prob: 0.0, seed: 11 }),
            max_attempts: 64,
            node_blacklist_failures: 2,
            speculative: false,
            n_reducers: 2,
            ..Default::default()
        };
        let (out, stats) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
        assert_eq!(out, ground_truth(&db));
        assert!(
            stats.nodes_blacklisted <= 1,
            "one node must survive: {} blacklisted",
            stats.nodes_blacklisted
        );
    }

    #[test]
    fn shuffle_fetch_faults_retry_then_reexecute_byte_identically() {
        use crate::chaos::{FaultClock, FaultPlan};
        let (cluster, db, splits) = fixture(2, 600);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let clean = JobRunner::new(&cluster, &dfs, &blocks)
            .run(&ItemCount, &db, &splits, &JobConfig { n_reducers: 2, ..Default::default() })
            .unwrap()
            .0;
        // task 0: two transient faults → retries absorb them;
        // task 1: a burst past the cap → map re-execution
        let clock = Arc::new(FaultClock::new(
            FaultPlan::parse("fetchfail:0:2@now;fetchfail:1:9@now").unwrap(),
        ));
        let runner = JobRunner::new(&cluster, &dfs, &blocks).with_chaos(Some(Arc::clone(&clock)));
        let (out, stats) = runner
            .run(&ItemCount, &db, &splits, &JobConfig { n_reducers: 2, ..Default::default() })
            .unwrap();
        assert_eq!(out, clean, "fetch recovery must not change the answer");
        assert!(stats.shuffle_fetch_retries >= 2, "got {}", stats.shuffle_fetch_retries);
        assert_eq!(stats.maps_reexecuted, 1, "task 1 re-executed exactly once");
    }

    #[test]
    fn slow_node_is_survived_and_results_unchanged() {
        use crate::chaos::{FaultClock, FaultPlan};
        let (cluster, db, splits) = fixture(2, 600);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let clock = Arc::new(FaultClock::new(FaultPlan::parse("slow:0:6@now").unwrap()));
        let runner = JobRunner::new(&cluster, &dfs, &blocks).with_chaos(Some(clock));
        let cfg = JobConfig { n_reducers: 2, ..Default::default() };
        let (out, _) = runner.run(&ItemCount, &db, &splits, &cfg).unwrap();
        assert_eq!(out, ground_truth(&db));
    }

    #[test]
    fn empty_input_produces_empty_output() {
        let cluster = ClusterConfig::fhssc(2);
        let db = TransactionDb::new(vec![]);
        let splits = plan_splits(&db, 10);
        let dfs = Dfs::new(&cluster);
        let runner = JobRunner::new(&cluster, &dfs, &[]);
        let (out, stats) = runner
            .run(&ItemCount, &db, &splits, &JobConfig::default())
            .unwrap();
        assert!(out.is_empty());
        assert_eq!(stats.maps_total, 0);
    }

    #[test]
    fn config_validation() {
        let (cluster, db, splits) = fixture(2, 100);
        let mut dfs = Dfs::new(&cluster);
        let blocks = dfs.write_splits(&splits).unwrap();
        let runner = JobRunner::new(&cluster, &dfs, &blocks);
        let cfg = JobConfig { n_reducers: 0, ..Default::default() };
        assert!(matches!(
            runner.run(&ItemCount, &db, &splits, &cfg),
            Err(JobError::NoReducers)
        ));
        let short = &blocks[..blocks.len() - 1];
        let runner = JobRunner::new(&cluster, &dfs, short);
        assert!(matches!(
            runner.run(&ItemCount, &db, &splits, &JobConfig::default()),
            Err(JobError::BadPlacement { .. })
        ));
    }
}

//! Shuffle: hash partitioning and sort-merge grouping.
//!
//! Hadoop semantics: each map task's output is partitioned by
//! `hash(key) % n_reducers`; each reducer pulls its partition from every
//! map, merge-sorts by key, and sees `(key, [values...])` groups in key
//! order. The combiner runs over a *single map task's* output before the
//! wire — it must be applied per-map, never across maps.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

/// Hadoop's `HashPartitioner`: stable across the process (we use a fixed
/// seed-free SipHash via `DefaultHasher` with identical initial state).
pub fn partition<K: Hash>(key: &K, n_reducers: usize) -> usize {
    assert!(n_reducers > 0);
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() % n_reducers as u64) as usize
}

/// Partition one map task's output into `n_reducers` buckets.
pub fn partition_output<K: Hash, V>(
    mut records: Vec<(K, V)>,
    n_reducers: usize,
) -> Vec<Vec<(K, V)>> {
    partition_drain(&mut records, n_reducers)
}

/// Partition by draining a reusable record buffer: the buckets are fresh
/// (they outlive the map task, parked until the shuffle pulls them) but
/// the source buffer keeps its capacity for the slot's next split.
/// Buckets are pre-sized from the map-output cardinality — an even-split
/// estimate, since the partitioner is built to spread keys.
pub fn partition_drain<K: Hash, V>(
    records: &mut Vec<(K, V)>,
    n_reducers: usize,
) -> Vec<Vec<(K, V)>> {
    let per_part = records.len() / n_reducers + 1;
    let mut parts: Vec<Vec<(K, V)>> = (0..n_reducers)
        .map(|_| Vec::with_capacity(per_part))
        .collect();
    for (k, v) in records.drain(..) {
        let p = partition(&k, n_reducers);
        parts[p].push((k, v));
    }
    parts
}

/// Group a reducer's pulled records by key, in key order (sort-merge).
pub fn group_by_key<K: Ord + Clone, V>(mut records: Vec<(K, V)>) -> Vec<(K, Vec<V>)> {
    records.sort_by(|a, b| a.0.cmp(&b.0));
    let mut groups: Vec<(K, Vec<V>)> = Vec::new();
    for (k, v) in records {
        match groups.last_mut() {
            Some((gk, vs)) if *gk == k => vs.push(v),
            _ => groups.push((k, vec![v])),
        }
    }
    groups
}

/// Apply a combiner to one map task's local output: group by key, fold
/// each group to a single record. `combine` returning `None` passes the
/// group through unchanged (no combiner configured for the app).
pub fn combine_local<K: Ord + Clone, V: Clone>(
    mut records: Vec<(K, V)>,
    combine: impl Fn(&K, &[V]) -> Option<V>,
) -> Vec<(K, V)> {
    combine_local_in_place(&mut records, combine, &mut Vec::new());
    records
}

/// The allocation-free combiner the map workers run per split: sort the
/// record buffer by key, fold each key run through `combine`, and
/// compact the survivors in place. `scratch` holds one run's values and
/// keeps its capacity across calls, so a worker slot combining thousands
/// of splits allocates nothing after the first.
pub fn combine_local_in_place<K: Ord, V: Clone>(
    records: &mut Vec<(K, V)>,
    combine: impl Fn(&K, &[V]) -> Option<V>,
    scratch: &mut Vec<V>,
) {
    records.sort_by(|a, b| a.0.cmp(&b.0));
    let n = records.len();
    let mut write = 0usize;
    let mut read = 0usize;
    while read < n {
        let mut end = read + 1;
        while end < n && records[end].0 == records[read].0 {
            end += 1;
        }
        scratch.clear();
        scratch.extend(records[read..end].iter().map(|(_, v)| v.clone()));
        match combine(&records[read].0, scratch) {
            Some(v) => {
                records.swap(write, read);
                records[write].1 = v;
                write += 1;
            }
            None => {
                // No combiner: keep the whole (key-sorted) run.
                for idx in read..end {
                    records.swap(write, idx);
                    write += 1;
                }
            }
        }
        read = end;
    }
    records.truncate(write);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn partition_is_stable_and_in_range() {
        for n in [1usize, 2, 3, 7, 16] {
            for key in 0u32..200 {
                let p1 = partition(&key, n);
                let p2 = partition(&key, n);
                assert_eq!(p1, p2);
                assert!(p1 < n);
            }
        }
    }

    #[test]
    fn partition_spreads_keys() {
        let n = 8;
        let mut hist = vec![0usize; n];
        for key in 0u32..8000 {
            hist[partition(&key, n)] += 1;
        }
        let (min, max) = (hist.iter().min().unwrap(), hist.iter().max().unwrap());
        assert!(
            *max < min * 2,
            "partition histogram too skewed: {hist:?}"
        );
    }

    #[test]
    fn partition_output_preserves_all_records() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let records: Vec<(u32, u64)> = (0..500)
            .map(|_| (rng.gen_range(100) as u32, rng.gen_range(10)))
            .collect();
        let parts = partition_output(records.clone(), 4);
        assert_eq!(parts.len(), 4);
        let mut flat: Vec<_> = parts.into_iter().flatten().collect();
        let mut orig = records;
        flat.sort_unstable();
        orig.sort_unstable();
        assert_eq!(flat, orig);
    }

    #[test]
    fn group_by_key_sorts_and_groups() {
        let groups = group_by_key(vec![(3, 'a'), (1, 'b'), (3, 'c'), (2, 'd'), (1, 'e')]);
        assert_eq!(
            groups,
            vec![(1, vec!['b', 'e']), (2, vec!['d']), (3, vec!['a', 'c'])]
        );
        assert!(group_by_key::<u32, ()>(vec![]).is_empty());
    }

    #[test]
    fn combine_local_sums() {
        let combined = combine_local(
            vec![(1u32, 1u64), (2, 1), (1, 1), (1, 1)],
            |_k, vs| Some(vs.iter().sum()),
        );
        assert_eq!(combined, vec![(1, 3), (2, 1)]);
    }

    #[test]
    fn combine_local_none_passthrough() {
        let recs = vec![(1u32, 1u64), (1, 2), (2, 3)];
        let out = combine_local(recs.clone(), |_k, _vs| None);
        assert_eq!(out, vec![(1, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn combine_in_place_matches_combine_local_and_reuses_buffers() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        let mut scratch: Vec<u64> = Vec::new();
        for _ in 0..50 {
            let records: Vec<(u32, u64)> = (0..rng.range_usize(0, 200))
                .map(|_| (rng.gen_range(15) as u32, rng.gen_range(5)))
                .collect();
            let want = combine_local(records.clone(), |_k, vs| Some(vs.iter().sum()));
            let mut got = records.clone();
            combine_local_in_place(&mut got, |_k, vs| Some(vs.iter().sum()), &mut scratch);
            assert_eq!(got, want);
            // passthrough (no combiner) keeps every record, key-sorted
            let want = combine_local(records.clone(), |_k, _vs: &[u64]| None);
            let mut got = records;
            combine_local_in_place(&mut got, |_k, _vs| None, &mut scratch);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn partition_drain_empties_but_keeps_capacity() {
        let mut records: Vec<(u32, u64)> = (0..100).map(|i| (i, 1)).collect();
        let cap = records.capacity();
        let parts = partition_drain(&mut records, 4);
        assert!(records.is_empty());
        assert_eq!(records.capacity(), cap, "scratch capacity must survive the drain");
        assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), 100);
        for part in &parts {
            assert!(part.capacity() >= 100 / 4, "buckets pre-sized from cardinality");
        }
    }

    #[test]
    fn combiner_equivalence_property() {
        // For an associative+commutative combiner, combine-then-reduce must
        // equal reduce-alone. This is the invariant that makes ablation A2
        // a pure performance experiment.
        let mut rng = Xoshiro256::seed_from_u64(9);
        for _ in 0..50 {
            let records: Vec<(u32, u64)> = (0..rng.range_usize(1, 300))
                .map(|_| (rng.gen_range(20) as u32, 1))
                .collect();
            let direct: Vec<(u32, u64)> = group_by_key(records.clone())
                .into_iter()
                .map(|(k, vs)| (k, vs.iter().sum()))
                .collect();
            let combined_first: Vec<(u32, u64)> = group_by_key(combine_local(
                records,
                |_k, vs: &[u64]| Some(vs.iter().sum()),
            ))
            .into_iter()
            .map(|(k, vs)| (k, vs.iter().sum()))
            .collect();
            assert_eq!(direct, combined_first);
        }
    }
}

//! Discrete-event cost model of a MapReduce job over simulated hardware.
//!
//! This is the substitute for the paper's physical testbed (DESIGN.md
//! §Substitutions): it reproduces the *shape* of fig 4/5 — who wins, where
//! the storage knee falls, how heterogeneity (FHDSC) degrades the makespan
//! — from first principles:
//!
//! * **map wave**: greedy earliest-finish-time list scheduling of map tasks
//!   onto per-node slots; a task reads its split from local disk when the
//!   chosen node holds a replica, over the network otherwise, with a
//!   read-amplification penalty on spilled blocks (storage over-commit);
//! * **shuffle**: a flow-level all-to-all transfer (`simnet`);
//! * **reduce wave**: reducers round-robin over nodes, gated by merge I/O
//!   and compute;
//! * **framework overheads**: per-task startup (Hadoop 0.20 forked a JVM
//!   per attempt) and per-job coordination that grows ~ln N with cluster
//!   size (namenode/jobtracker chatter) — the term the paper's
//!   `FHDSC = FHSSC = ln N` model gestures at.
//!
//! Durations are deterministic functions of `NodeProfile`s, so every curve
//! in the benches is exactly reproducible.

use crate::cluster::{ClusterConfig, DeployMode, NodeId};
use crate::simnet::Network;

/// One map task as the simulator sees it.
#[derive(Debug, Clone)]
pub struct SimMapTask {
    /// Split size on disk.
    pub bytes: u64,
    /// Compute cost in work units (1 unit = one tx·candidate probe).
    pub work: f64,
    /// Nodes holding a replica of the backing block.
    pub replicas: Vec<NodeId>,
    /// Block was placed past node capacity (fig-5 knee).
    pub spilled: bool,
}

/// One job description.
#[derive(Debug, Clone)]
pub struct SimJobSpec {
    pub map_tasks: Vec<SimMapTask>,
    pub n_reducers: usize,
    /// Total shuffle bytes produced by each map task (spread uniformly
    /// over reducers).
    pub shuffle_bytes_per_map: u64,
    /// Compute cost per reducer, work units.
    pub reduce_work: f64,
    /// Model speculative re-execution of stragglers.
    pub speculative: bool,
    /// Unexpected degradation: `(node, factor)` multiplies the runtime of
    /// every task assigned to `node` *after* scheduling — the classic
    /// straggler scenario (thermal throttling, a busy neighbour, a dying
    /// disk) that the scheduler could not have planned around and that
    /// speculative execution exists to absorb.
    pub surprise: Option<(NodeId, f64)>,
}

impl Default for SimJobSpec {
    fn default() -> Self {
        Self {
            map_tasks: Vec::new(),
            n_reducers: 1,
            shuffle_bytes_per_map: 0,
            reduce_work: 0.0,
            speculative: false,
            surprise: None,
        }
    }
}

/// Framework cost constants. Defaults follow Hadoop-0.20-era folklore:
/// ~1 s JVM fork per task, seconds of job setup, coordination growing
/// with ln(cluster size).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Per-attempt startup (JVM fork + localization), seconds.
    pub task_startup_s: f64,
    /// Fixed per-job setup/teardown, seconds.
    pub job_startup_s: f64,
    /// Coefficient of the ln(N) coordination term, seconds.
    pub coordination_s: f64,
    /// Read amplification on spilled blocks.
    pub spill_penalty: f64,
    /// Reference node throughput, work units / second at cpu_factor 1.0.
    pub work_units_per_sec: f64,
}

impl CostModel {
    /// Defaults per deployment mode (standalone skips the framework).
    pub fn for_mode(mode: DeployMode) -> Self {
        match mode {
            DeployMode::Standalone => Self {
                task_startup_s: 0.0,
                job_startup_s: 0.0,
                coordination_s: 0.0,
                spill_penalty: 3.0,
                work_units_per_sec: 2.0e6,
            },
            DeployMode::PseudoDistributed => Self {
                task_startup_s: 1.0,
                job_startup_s: 4.0,
                coordination_s: 0.0,
                spill_penalty: 3.0,
                work_units_per_sec: 2.0e6,
            },
            DeployMode::FullyDistributed => Self {
                task_startup_s: 1.0,
                job_startup_s: 4.0,
                coordination_s: 2.0,
                spill_penalty: 3.0,
                work_units_per_sec: 2.0e6,
            },
        }
    }
}

/// Phase timings of one simulated job.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub startup_secs: f64,
    pub map_secs: f64,
    pub shuffle_secs: f64,
    pub reduce_secs: f64,
    pub total_secs: f64,
    /// Fraction of map tasks that ran data-local.
    pub locality_fraction: f64,
    /// Fraction of map tasks that paid the spill penalty.
    pub spill_fraction: f64,
    /// Map tasks sped up by speculative re-execution.
    pub speculated: usize,
}

/// The simulator: cluster + cost model.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub cluster: ClusterConfig,
    pub cost: CostModel,
}

#[derive(Debug, Clone, Copy)]
struct Slot {
    node: NodeId,
    free_at: f64,
}

impl Simulator {
    pub fn new(cluster: ClusterConfig) -> Self {
        let cost = CostModel::for_mode(cluster.mode);
        Self { cluster, cost }
    }

    pub fn with_cost(cluster: ClusterConfig, cost: CostModel) -> Self {
        Self { cluster, cost }
    }

    fn network(&self) -> Network {
        Network::new(
            self.cluster.switch.clone(),
            self.cluster.nodes.iter().map(|n| n.nic_mbps).collect(),
        )
        // inter-rack uplink: a quarter of the backplane (oversubscribed
        // top-of-rack), only binding for multi-rack layouts.
        .with_racks(
            self.cluster.rack_of.clone(),
            self.cluster.switch.backplane_mbps / 4.0,
        )
    }

    /// Map-task duration on a given node.
    fn map_duration(&self, t: &SimMapTask, node: NodeId) -> f64 {
        let p = &self.cluster.nodes[node];
        let local = t.replicas.contains(&node);
        let disk = t.bytes as f64 / (p.disk_mbps * 1e6);
        let read = if local {
            disk
        } else {
            // remote read: the remote disk still serves the bytes, then
            // they cross the switch gated by this node's NIC — a
            // store-and-forward (non-pipelined) approximation, which is
            // what makes data-locality scheduling worth having.
            let net =
                t.bytes as f64 * 8.0 / (p.nic_mbps.min(self.cluster.switch.port_mbps) * 1e6);
            disk + net + self.cluster.switch.latency_ms / 1e3
        };
        let compute = t.work / (self.cost.work_units_per_sec * p.cpu_factor);
        // Storage over-commit degrades the whole task, not just the read:
        // once disks are full, intermediate files (the paper's "superset
        // transaction generation") spill remotely and spill-merge passes
        // thrash — the mechanism §4 blames for the fig-5 exponential tail.
        let spill = if t.spilled { self.cost.spill_penalty } else { 1.0 };
        self.cost.task_startup_s + (read + compute) * spill
    }

    /// Simulate one job; returns phase timings.
    pub fn run(&self, spec: &SimJobSpec) -> SimReport {
        let n_nodes = self.cluster.n_nodes();
        let mut report = SimReport::default();

        // ---- startup + coordination ----
        report.startup_secs = self.cost.job_startup_s
            + self.cost.coordination_s * (n_nodes.max(1) as f64).ln().max(0.0);

        // ---- map wave: pull-based scheduling, like the real jobtracker —
        // when a slot frees it pulls the first pending task local to its
        // node (else the queue head). Slot availability evolves with
        // *actual* durations (including the post-scheduling surprise), so
        // a degraded node naturally pulls fewer tasks; what's left is the
        // tail a running straggler gates — speculation's job.
        let mut slots: Vec<Slot> = Vec::new();
        for (node, p) in self.cluster.nodes.iter().enumerate() {
            for _ in 0..p.slots {
                slots.push(Slot { node, free_at: 0.0 });
            }
        }
        let n_tasks = spec.map_tasks.len();
        let mut pending: Vec<usize> = (0..n_tasks).collect();
        let mut map_node: Vec<NodeId> = vec![0; n_tasks];
        let mut task_start = vec![0.0f64; n_tasks];
        let mut task_finish = vec![0.0f64; n_tasks];
        let mut actual = vec![0.0f64; n_tasks];
        let mut local_count = 0usize;
        while !pending.is_empty() {
            // earliest-free slot pulls next (deterministic tie-break).
            let si = slots
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.free_at.total_cmp(&b.1.free_at).then(a.0.cmp(&b.0)))
                .map(|(i, _)| i)
                .unwrap();
            let node = slots[si].node;
            let pos = pending
                .iter()
                .position(|&ti| spec.map_tasks[ti].replicas.contains(&node))
                .unwrap_or(0);
            let ti = pending.remove(pos);
            let t = &spec.map_tasks[ti];
            if t.replicas.contains(&node) {
                local_count += 1;
            }
            let mut dur = self.map_duration(t, node);
            if let Some((slow_node, factor)) = spec.surprise {
                if node == slow_node {
                    dur *= factor.max(1.0);
                }
            }
            map_node[ti] = node;
            actual[ti] = dur;
            task_start[ti] = slots[si].free_at;
            slots[si].free_at += dur;
            task_finish[ti] = slots[si].free_at;
        }
        let mut map_finish = task_finish.iter().cloned().fold(0.0f64, f64::max);

        // Phase D: speculative execution — a task whose actual runtime
        // exceeds `2 × median` gets a duplicate on the earliest-free slot
        // of a *different* node; the earlier finisher wins.
        if spec.speculative && actual.len() > 2 {
            let mut sorted = actual.clone();
            sorted.sort_by(f64::total_cmp);
            let median = sorted[sorted.len() / 2];
            let mut slot_free: Vec<f64> = slots.iter().map(|s| s.free_at).collect();
            for ti in 0..actual.len() {
                if actual[ti] > 2.0 * median {
                    // backup launched when the straggler is detected
                    // (median elapsed), on the earliest-free foreign slot.
                    let (bs, bfree) = slot_free
                        .iter()
                        .enumerate()
                        .filter(|(si, _)| slots[*si].node != map_node[ti])
                        .map(|(si, &f)| (si, f))
                        .min_by(|a, b| a.1.total_cmp(&b.1))
                        .map(|(si, f)| (Some(si), f))
                        .unwrap_or((None, f64::INFINITY));
                    if let Some(bs) = bs {
                        let detect = task_start[ti] + median;
                        let backup_start = bfree.max(detect);
                        // the backup reads remotely at median compute speed
                        let dup = self.cost.task_startup_s + median * 1.2;
                        let dup_finish = backup_start + dup;
                        if dup_finish < task_finish[ti] {
                            task_finish[ti] = dup_finish;
                            slot_free[bs] = dup_finish;
                            report.speculated += 1;
                        }
                    }
                }
            }
            map_finish = task_finish.iter().cloned().fold(0.0f64, f64::max);
        }
        report.map_secs = map_finish;
        report.locality_fraction = if spec.map_tasks.is_empty() {
            1.0
        } else {
            local_count as f64 / spec.map_tasks.len() as f64
        };
        report.spill_fraction = if spec.map_tasks.is_empty() {
            0.0
        } else {
            spec.map_tasks.iter().filter(|t| t.spilled).count() as f64
                / spec.map_tasks.len() as f64
        };

        // ---- shuffle: all-to-all flow matrix ----
        if spec.n_reducers > 0 && spec.shuffle_bytes_per_map > 0 && !spec.map_tasks.is_empty() {
            let per_reducer = spec.shuffle_bytes_per_map / spec.n_reducers.max(1) as u64;
            let mut matrix = vec![vec![0u64; n_nodes]; n_nodes];
            for (m, &src) in map_node.iter().enumerate() {
                let _ = m;
                for r in 0..spec.n_reducers {
                    let dst = r % n_nodes; // reducers round-robin on nodes
                    matrix[src][dst] += per_reducer;
                }
            }
            report.shuffle_secs = self.network().shuffle_makespan(&matrix);
        }

        // ---- reduce wave ----
        if spec.n_reducers > 0 {
            let total_shuffle: u64 =
                spec.shuffle_bytes_per_map * spec.map_tasks.len() as u64;
            let bytes_per_reducer = total_shuffle / spec.n_reducers as u64;
            let mut slot_free = vec![0.0f64; n_nodes];
            let mut finish = 0.0f64;
            for r in 0..spec.n_reducers {
                let node = r % n_nodes;
                let p = &self.cluster.nodes[node];
                // merge-sort I/O + compute
                let io = bytes_per_reducer as f64 / (p.disk_mbps * 1e6);
                let compute =
                    spec.reduce_work / (self.cost.work_units_per_sec * p.cpu_factor);
                let dur = self.cost.task_startup_s + io + compute;
                slot_free[node] += dur;
                finish = finish.max(slot_free[node]);
            }
            report.reduce_secs = finish;
        }

        report.total_secs =
            report.startup_secs + report.map_secs + report.shuffle_secs + report.reduce_secs;
        report
    }

    /// Pipelined job DAG: job k+1 is submitted when job k's map wave
    /// starts (its candidates exist by then), so its setup/coordination
    /// runs concurrently with job k's waves — but still gates job k+1's
    /// own maps, which additionally wait for the map slots to drain.
    /// Job k's shuffle + reduce overlap the successor's maps on the lanes
    /// the map wave freed. `startup_secs` still accounts every job's
    /// setup (the work exists; overlap only hides it from the critical
    /// path), and `total_secs` is the pipelined **makespan** — the latest
    /// reduce finish — not the sum of per-job totals that the synchronous
    /// [`run_sequence`](Self::run_sequence) reports.
    pub fn run_pipelined_sequence(&self, specs: &[SimJobSpec]) -> SimReport {
        let mut total = SimReport { locality_fraction: 1.0, ..Default::default() };
        let mut loc_acc = 0.0;
        let mut map_cursor = 0.0f64; // when the map slots next come free
        let mut prev_map_start = 0.0f64;
        let mut makespan = 0.0f64;
        for (j, s) in specs.iter().enumerate() {
            let r = self.run(s);
            total.startup_secs += r.startup_secs;
            let map_start = if j == 0 {
                r.startup_secs
            } else {
                // submitted at the predecessor's map start; setup overlaps
                // the predecessor's waves but cannot be skipped outright.
                map_cursor.max(prev_map_start + r.startup_secs)
            };
            let map_end = map_start + r.map_secs;
            let finish = map_end + r.shuffle_secs + r.reduce_secs;
            prev_map_start = map_start;
            map_cursor = map_end;
            makespan = makespan.max(finish);
            total.map_secs += r.map_secs;
            total.shuffle_secs += r.shuffle_secs;
            total.reduce_secs += r.reduce_secs;
            total.speculated += r.speculated;
            loc_acc += r.locality_fraction;
            total.spill_fraction = total.spill_fraction.max(r.spill_fraction);
        }
        if !specs.is_empty() {
            total.locality_fraction = loc_acc / specs.len() as f64;
        }
        total.total_secs = makespan;
        total
    }

    /// Sum of several jobs run back-to-back (Apriori's level-wise loop).
    pub fn run_sequence(&self, specs: &[SimJobSpec]) -> SimReport {
        let mut total = SimReport { locality_fraction: 1.0, ..Default::default() };
        let mut loc_acc = 0.0;
        for s in specs {
            let r = self.run(s);
            total.startup_secs += r.startup_secs;
            total.map_secs += r.map_secs;
            total.shuffle_secs += r.shuffle_secs;
            total.reduce_secs += r.reduce_secs;
            total.total_secs += r.total_secs;
            total.speculated += r.speculated;
            loc_acc += r.locality_fraction;
            total.spill_fraction = total.spill_fraction.max(r.spill_fraction);
        }
        if !specs.is_empty() {
            total.locality_fraction = loc_acc / specs.len() as f64;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_tasks(n: usize, bytes: u64, work: f64, n_nodes: usize) -> Vec<SimMapTask> {
        (0..n)
            .map(|i| SimMapTask {
                bytes,
                work,
                replicas: vec![i % n_nodes, (i + 1) % n_nodes],
                spilled: false,
            })
            .collect()
    }

    fn spec(n_maps: usize, n_nodes: usize) -> SimJobSpec {
        SimJobSpec {
            map_tasks: uniform_tasks(n_maps, 8_000_000, 4.0e6, n_nodes),
            n_reducers: n_nodes,
            shuffle_bytes_per_map: 500_000,
            reduce_work: 1.0e6,
            ..Default::default()
        }
    }

    #[test]
    fn more_nodes_speed_up_large_jobs() {
        let t3 = Simulator::new(ClusterConfig::fhssc(3)).run(&spec(64, 3)).total_secs;
        let t6 = Simulator::new(ClusterConfig::fhssc(6)).run(&spec(64, 6)).total_secs;
        assert!(t6 < t3, "6 nodes {t6} should beat 3 nodes {t3}");
    }

    #[test]
    fn fhdsc_slower_than_fhssc_at_equal_n() {
        for n in [2, 3, 5, 8] {
            let hom = Simulator::new(ClusterConfig::fhssc(n)).run(&spec(48, n)).total_secs;
            let het = Simulator::new(ClusterConfig::fhdsc(n)).run(&spec(48, n)).total_secs;
            assert!(
                het > hom,
                "n={n}: FHDSC {het} must be slower than FHSSC {hom} (paper fig 4)"
            );
        }
    }

    #[test]
    fn standalone_beats_distributed_on_tiny_inputs() {
        // The paper's fig-5 crossover: framework overhead dominates small
        // jobs, parallelism wins large ones.
        let tiny = SimJobSpec {
            map_tasks: uniform_tasks(2, 100_000, 1.0e5, 1),
            n_reducers: 1,
            shuffle_bytes_per_map: 10_000,
            reduce_work: 1.0e4,
            ..Default::default()
        };
        let sa = Simulator::new(ClusterConfig::standalone()).run(&tiny).total_secs;
        let fd = Simulator::new(ClusterConfig::fhssc(3)).run(&tiny).total_secs;
        assert!(sa < fd, "standalone {sa} must beat distributed {fd} on tiny input");

        let big = spec(96, 3);
        let mut big_sa = big.clone();
        for t in &mut big_sa.map_tasks {
            t.replicas = vec![0];
        }
        let sa_big = Simulator::new(ClusterConfig::standalone()).run(&big_sa).total_secs;
        let fd_big = Simulator::new(ClusterConfig::fhssc(3)).run(&big).total_secs;
        assert!(fd_big < sa_big, "distributed {fd_big} must beat standalone {sa_big} on big input");
    }

    #[test]
    fn spilled_blocks_inflate_map_time() {
        let n = 3;
        let mut clean = spec(32, n);
        let mut spilled = clean.clone();
        for t in &mut spilled.map_tasks {
            t.spilled = true;
        }
        let sim = Simulator::new(ClusterConfig::fhssc(n));
        let tc = sim.run(&clean).total_secs;
        let ts = sim.run(&spilled).total_secs;
        assert!(ts > tc, "spill must cost: {ts} vs {tc}");
        clean.map_tasks.truncate(0);
        assert!(sim.run(&clean).map_secs == 0.0);
    }

    #[test]
    fn remote_reads_slower_than_local() {
        let sim = Simulator::new(ClusterConfig::fhssc(3));
        let local = SimMapTask {
            bytes: 64_000_000,
            work: 0.0,
            replicas: vec![0],
            spilled: false,
        };
        let d_local = sim.map_duration(&local, 0);
        let d_remote = sim.map_duration(&local, 1);
        assert!(d_remote > d_local, "{d_remote} vs {d_local}");
    }

    #[test]
    fn speculation_reduces_makespan_with_straggler() {
        // Node 3 unexpectedly degrades 10x after scheduling: without
        // speculation its tasks gate the wave.
        let sim = Simulator::new(ClusterConfig::fhssc(4));
        let mut s = spec(32, 4);
        s.surprise = Some((3, 10.0));
        s.speculative = false;
        let without = sim.run(&s).total_secs;
        s.speculative = true;
        let with_spec = sim.run(&s);
        assert!(with_spec.speculated > 0, "straggler should trigger speculation");
        assert!(
            with_spec.total_secs < without,
            "speculation must help: {} vs {without}",
            with_spec.total_secs
        );
        // and a surprise with speculation still beats no mitigation
        let mut clean = spec(32, 4);
        clean.speculative = false;
        assert!(without > sim.run(&clean).total_secs, "surprise must cost something");
    }

    #[test]
    fn coordination_overhead_grows_logarithmically() {
        let r2 = Simulator::new(ClusterConfig::fhssc(2)).run(&spec(4, 2));
        let r16 = Simulator::new(ClusterConfig::fhssc(16)).run(&spec(4, 16));
        let delta = r16.startup_secs - r2.startup_secs;
        let expected = 2.0 * ((16f64).ln() - (2f64).ln());
        assert!((delta - expected).abs() < 1e-9, "delta {delta} vs {expected}");
    }

    #[test]
    fn sequence_sums_jobs() {
        let sim = Simulator::new(ClusterConfig::fhssc(3));
        let s = spec(8, 3);
        let one = sim.run(&s).total_secs;
        let three = sim.run_sequence(&[s.clone(), s.clone(), s]).total_secs;
        assert!((three - 3.0 * one).abs() < 1e-9);
    }

    #[test]
    fn pipelined_sequence_beats_synchronous_and_is_deterministic() {
        let sim = Simulator::new(ClusterConfig::fhssc(3));
        let specs = [spec(16, 3), spec(12, 3), spec(8, 3), spec(4, 3)];
        let sync = sim.run_sequence(&specs);
        let piped = sim.run_pipelined_sequence(&specs);
        assert!(
            piped.total_secs < sync.total_secs,
            "pipelined {} must beat synchronous {}",
            piped.total_secs,
            sync.total_secs
        );
        // phases still account for the same work, only the timeline overlaps
        assert_eq!(piped.startup_secs.to_bits(), sync.startup_secs.to_bits());
        assert_eq!(piped.map_secs.to_bits(), sync.map_secs.to_bits());
        assert_eq!(piped.reduce_secs.to_bits(), sync.reduce_secs.to_bits());
        // makespan can never undercut the serialized map waves
        assert!(piped.total_secs >= piped.map_secs);
        let again = sim.run_pipelined_sequence(&specs);
        assert_eq!(piped.total_secs.to_bits(), again.total_secs.to_bits());
    }

    #[test]
    fn pipelined_setup_not_free_without_overlap_capacity() {
        // Jobs with (near) nothing to hide setup under: tiny maps, no
        // shuffle, no reduce. The pipelined makespan must still pay every
        // job's setup on the critical path rather than erasing it.
        let sim = Simulator::new(ClusterConfig::fhssc(3));
        let tiny = SimJobSpec {
            map_tasks: uniform_tasks(1, 1_000, 1.0, 3),
            n_reducers: 1,
            shuffle_bytes_per_map: 0,
            reduce_work: 0.0,
            ..Default::default()
        };
        let specs = [tiny.clone(), tiny.clone(), tiny];
        let piped = sim.run_pipelined_sequence(&specs);
        assert!(
            piped.total_secs >= piped.startup_secs,
            "pipelined makespan {} must not undercut the serialized setups {}",
            piped.total_secs,
            piped.startup_secs
        );
    }

    #[test]
    fn pipelined_single_job_matches_run() {
        let sim = Simulator::new(ClusterConfig::fhssc(3));
        let s = spec(8, 3);
        let one = sim.run(&s);
        let piped = sim.run_pipelined_sequence(std::slice::from_ref(&s));
        assert_eq!(one.total_secs.to_bits(), piped.total_secs.to_bits());
        assert!(sim.run_pipelined_sequence(&[]).total_secs == 0.0);
    }
}

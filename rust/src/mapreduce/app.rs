//! The application contract: what a MapReduce job supplies.
//!
//! Mirrors Hadoop's `Mapper`/`Combiner`/`Reducer` trio over typed keys and
//! values instead of `Writable` byte streams. Inputs are transaction
//! slices (this system's InputFormat); emission goes through a collector
//! closure exactly like `context.write(k, v)`.

use std::fmt::Debug;
use std::hash::Hash;

use crate::data::{split::Split, Transaction};

/// A MapReduce application over typed keys/values.
pub trait MapReduceApp: Send + Sync {
    /// Intermediate/output key. `Ord + Hash` because the shuffle both
    /// hash-partitions and sort-merges (Hadoop semantics: reducer input
    /// arrives key-sorted). `Sync` because tasktracker threads share the
    /// jobtracker's output store by reference.
    type K: Ord + Hash + Clone + Send + Sync + Debug + 'static;
    /// Value type.
    type V: Clone + Send + Sync + Debug + 'static;

    /// Map one input split. `emit` corresponds to `context.write`.
    fn map(
        &self,
        split: &Split,
        input: &[Transaction],
        emit: &mut dyn FnMut(Self::K, Self::V),
    );

    /// Optional map-side combiner over one key's values from a single map
    /// task. Returning `None` disables combining for this app.
    fn combine(&self, _key: &Self::K, _values: &[Self::V]) -> Option<Self::V> {
        None
    }

    /// Reduce one key group. Returning `None` drops the key from the
    /// output (Apriori uses this for the min-support filter).
    fn reduce(&self, key: &Self::K, values: &[Self::V]) -> Option<Self::V>;

    /// Abstract compute cost of mapping `n_tx` transactions, in work units
    /// (1 unit ≈ one transaction·candidate containment probe). Drives the
    /// simulator and the stats; the default is linear in input size.
    fn map_cost_hint(&self, n_tx: usize) -> f64 {
        n_tx as f64
    }

    /// Abstract compute cost of reducing one key group.
    fn reduce_cost_hint(&self, n_values: usize) -> f64 {
        n_values as f64
    }

    /// Approximate serialized size in bytes of one (key, value) record on
    /// the shuffle wire (drives the simulator's shuffle matrix).
    fn record_bytes_hint(&self) -> usize {
        16
    }

    /// How many broadcast candidates each map task counts against its
    /// split — a Hadoop-style job counter the tracer stamps on every
    /// map-task span. Apps without a candidate set report 0.
    fn n_candidates(&self) -> usize {
        0
    }
}

/// A trivial word-count-style app over item ids, used by the substrate's
/// own tests (the Apriori apps live in `apriori::mr`).
pub struct ItemCount;

impl MapReduceApp for ItemCount {
    type K = u32;
    type V = u64;

    fn map(&self, _s: &Split, input: &[Transaction], emit: &mut dyn FnMut(u32, u64)) {
        for t in input {
            for &item in &t.items {
                emit(item, 1);
            }
        }
    }

    fn combine(&self, _k: &u32, values: &[u64]) -> Option<u64> {
        Some(values.iter().sum())
    }

    fn reduce(&self, _k: &u32, values: &[u64]) -> Option<u64> {
        Some(values.iter().sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::split::{plan_splits, split_transactions};
    use crate::data::TransactionDb;

    #[test]
    fn item_count_maps_and_combines() {
        let db = TransactionDb::new(vec![
            Transaction::new([0u32, 1]),
            Transaction::new([1u32]),
        ]);
        let splits = plan_splits(&db, 10);
        let mut out = Vec::new();
        ItemCount.map(&splits[0], split_transactions(&db, &splits[0]), &mut |k, v| {
            out.push((k, v))
        });
        out.sort_unstable();
        assert_eq!(out, vec![(0, 1), (1, 1), (1, 1)]);
        assert_eq!(ItemCount.combine(&1, &[1, 1]), Some(2));
        assert_eq!(ItemCount.reduce(&1, &[2, 5]), Some(7));
    }

    #[test]
    fn default_hints_are_sane() {
        assert_eq!(ItemCount.map_cost_hint(100), 100.0);
        assert_eq!(ItemCount.reduce_cost_hint(3), 3.0);
        assert!(ItemCount.record_bytes_hint() > 0);
    }
}

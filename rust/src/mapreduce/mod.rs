//! The Hadoop-like MapReduce substrate.
//!
//! Two execution engines share the same job description:
//!
//! * [`runner`] — **real** multi-threaded execution: per-node tasktracker
//!   pools sized by slot count, a jobtracker with locality-aware FIFO
//!   scheduling, hash-partitioned sort-merge shuffle, optional combiner,
//!   speculative re-execution of stragglers, and failure injection with
//!   bounded retry. Produces actual results and wall-clock stats.
//! * [`sim`] — a **discrete-event cost model** of the same schedule over
//!   the paper's hardware profiles (`cluster`, `simnet`, `dfs`): map waves
//!   on slots with data-locality and spill penalties, flow-level shuffle,
//!   reduce waves, and Hadoop's fixed per-task/per-job overheads. This is
//!   what regenerates the paper's fig 4/5 *shapes* on one machine.
//!
//! Apriori (or any other application) implements [`app::MapReduceApp`] and
//! runs unchanged on either engine.

pub mod app;
pub mod runner;
pub mod shuffle;
pub mod sim;

pub use app::MapReduceApp;
pub use runner::{JobConfig, JobError, JobRunner, JobStats, MapOutputs};
pub use sim::{SimJobSpec, SimMapTask, SimReport, Simulator};

use crate::cluster::ClusterConfig;
use crate::data::split::plan_splits;
use crate::data::TransactionDb;
use crate::dfs::{Dfs, DfsError};

/// What a one-shot ad-hoc job can fail with: block placement or job
/// execution (the coordinator's `MineError` wraps the same pair).
#[derive(Debug)]
pub enum AdhocJobError {
    Dfs(DfsError),
    Job(JobError),
}

impl std::fmt::Display for AdhocJobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Dfs(e) => write!(f, "dfs: {e}"),
            Self::Job(e) => write!(f, "job: {e}"),
        }
    }
}

impl std::error::Error for AdhocJobError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Dfs(e) => Some(e),
            Self::Job(e) => Some(e),
        }
    }
}

impl From<DfsError> for AdhocJobError {
    fn from(e: DfsError) -> Self {
        Self::Dfs(e)
    }
}

impl From<JobError> for AdhocJobError {
    fn from(e: JobError) -> Self {
        Self::Job(e)
    }
}

/// Run one app over an ad-hoc database outside the coordinator's level
/// loop: plan splits, place them in a fresh DFS, execute to completion.
/// An empty database runs zero map tasks and returns an empty output.
///
/// This is the one-shot wiring the incremental subsystem's delta jobs
/// (`incremental::delta_job`) use — plan, place, run, discard. Repeated
/// scans over the same database belong on `coordinator::ExactCounter`
/// instead, which keeps the placement across jobs. The app itself may
/// still carry longer-lived state through the runner — the delta job
/// attaches the driver's resident index cache (`engine::IndexCache`)
/// so its map tasks reuse per-split index builds under a fresh
/// generation even though the DFS placement is throwaway.
pub fn run_adhoc<A: MapReduceApp>(
    cluster: &ClusterConfig,
    db: &TransactionDb,
    split_tx: usize,
    app: &A,
    cfg: &JobConfig,
) -> Result<(Vec<(A::K, A::V)>, JobStats), AdhocJobError> {
    run_adhoc_chaos(cluster, db, split_tx, app, cfg, None)
}

/// [`run_adhoc`] under a shared fault clock: already-dead nodes are
/// reaped from the fresh DFS before placement (so locality scheduling
/// works over survivors), and a job stranded by nodes lost *mid-run* is
/// retried once against the reaped placement — the delta jobs' node-loss
/// recovery. With `chaos = None` this is exactly [`run_adhoc`].
pub fn run_adhoc_chaos<A: MapReduceApp>(
    cluster: &ClusterConfig,
    db: &TransactionDb,
    split_tx: usize,
    app: &A,
    cfg: &JobConfig,
    chaos: Option<&std::sync::Arc<crate::chaos::FaultClock>>,
) -> Result<(Vec<(A::K, A::V)>, JobStats), AdhocJobError> {
    let splits = plan_splits(db, split_tx);
    let mut dfs = Dfs::new(cluster);
    if let Some(clock) = chaos {
        dfs.reap_dead_nodes(&clock.dead_nodes());
    }
    let blocks = dfs.write_splits(&splits)?;
    let first = JobRunner::new(cluster, &dfs, &blocks)
        .with_chaos(chaos.map(std::sync::Arc::clone))
        .run(app, db, &splits, cfg);
    match first {
        Err(JobError::NodesLost { .. }) if chaos.is_some_and(|c| !c.dead_nodes().is_empty()) => {
            let clock = chaos.expect("guarded");
            if clock.dead_nodes().len() >= cluster.n_nodes() {
                return Err(JobError::NodesLost {
                    pending: splits.len(),
                    dead: clock.dead_nodes().len(),
                }
                .into());
            }
            let mut dfs = Dfs::new(cluster);
            dfs.reap_dead_nodes(&clock.dead_nodes());
            let blocks = dfs.write_splits(&splits)?;
            let runner = JobRunner::new(cluster, &dfs, &blocks)
                .with_chaos(Some(std::sync::Arc::clone(clock)));
            Ok(runner.run(app, db, &splits, cfg)?)
        }
        other => Ok(other?),
    }
}

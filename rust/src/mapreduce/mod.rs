//! The Hadoop-like MapReduce substrate.
//!
//! Two execution engines share the same job description:
//!
//! * [`runner`] — **real** multi-threaded execution: per-node tasktracker
//!   pools sized by slot count, a jobtracker with locality-aware FIFO
//!   scheduling, hash-partitioned sort-merge shuffle, optional combiner,
//!   speculative re-execution of stragglers, and failure injection with
//!   bounded retry. Produces actual results and wall-clock stats.
//! * [`sim`] — a **discrete-event cost model** of the same schedule over
//!   the paper's hardware profiles (`cluster`, `simnet`, `dfs`): map waves
//!   on slots with data-locality and spill penalties, flow-level shuffle,
//!   reduce waves, and Hadoop's fixed per-task/per-job overheads. This is
//!   what regenerates the paper's fig 4/5 *shapes* on one machine.
//!
//! Apriori (or any other application) implements [`app::MapReduceApp`] and
//! runs unchanged on either engine.

pub mod app;
pub mod runner;
pub mod shuffle;
pub mod sim;

pub use app::MapReduceApp;
pub use runner::{JobConfig, JobError, JobRunner, JobStats, MapOutputs};
pub use sim::{SimJobSpec, SimMapTask, SimReport, Simulator};

//! Exporters: Chrome `trace_event` JSON (Perfetto / `about://tracing`
//! loadable), a JSONL event log, and the one-page plain-text metrics
//! dump.
//!
//! Both trace formats are emitted from the same [`TraceEvent`] buffer:
//! the Chrome file is what `mine --trace-out` / `serve --trace-out`
//! write (and `tools/trace_check.py` validates in CI); the JSONL
//! sibling (`<trace-out>` with an `.jsonl` extension) is the
//! machine-readable event log for ad-hoc analysis — one compact JSON
//! object per line, no enclosing array to parse.

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use crate::util::json::Json;

use super::registry::{MetricValue, MetricsSnapshot};
use super::trace::TraceEvent;

/// One trace event as a Chrome `trace_event` "complete" (`ph: "X"`)
/// record. The span/parent/trace ids ride in `args` next to the job
/// counters — the viewer shows them on click, `trace_check.py` uses
/// them to verify the tree.
fn chrome_event(ev: &TraceEvent) -> Json {
    let mut args = BTreeMap::new();
    args.insert("trace_id".to_string(), Json::num(ev.trace_id as f64));
    args.insert("span_id".to_string(), Json::num(ev.span_id as f64));
    args.insert("parent_id".to_string(), Json::num(ev.parent_id as f64));
    for (k, v) in &ev.args {
        args.insert(k.clone(), Json::num(*v));
    }
    Json::obj(vec![
        ("name", Json::str(ev.name.clone())),
        ("cat", Json::str(ev.cat)),
        ("ph", Json::str("X")),
        ("ts", Json::num(ev.start_us as f64)),
        ("dur", Json::num(ev.dur_us as f64)),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(ev.tid as f64)),
        ("args", Json::Obj(args)),
    ])
}

/// Render the full Chrome `trace_event` document.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    Json::obj(vec![
        ("traceEvents", Json::Arr(events.iter().map(chrome_event).collect())),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Write the Perfetto-loadable Chrome trace file.
pub fn write_chrome_trace(path: impl AsRef<Path>, events: &[TraceEvent]) -> io::Result<()> {
    std::fs::write(path, format!("{}\n", chrome_trace_json(events)))
}

/// Write the JSONL event log: one flat object per completed span.
pub fn write_jsonl(path: impl AsRef<Path>, events: &[TraceEvent]) -> io::Result<()> {
    let mut out = String::new();
    for ev in events {
        let mut fields = vec![
            ("name", Json::str(ev.name.clone())),
            ("cat", Json::str(ev.cat)),
            ("trace_id", Json::num(ev.trace_id as f64)),
            ("span_id", Json::num(ev.span_id as f64)),
            ("parent_id", Json::num(ev.parent_id as f64)),
            ("start_us", Json::num(ev.start_us as f64)),
            ("dur_us", Json::num(ev.dur_us as f64)),
            ("tid", Json::num(ev.tid as f64)),
        ];
        let args: BTreeMap<String, Json> = ev
            .args
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(*v)))
            .collect();
        fields.push(("args", Json::Obj(args)));
        out.push_str(&Json::obj(fields).to_string());
        out.push('\n');
    }
    std::fs::write(path, out)
}

/// The one-page plain-text dump of a metrics cut, sorted by key —
/// printed per refresh cycle and at exit when observability is on.
pub fn render_metrics(snapshot: &MetricsSnapshot) -> String {
    let mut out = String::from("== metrics ==\n");
    if snapshot.entries.is_empty() {
        out.push_str("(no instruments registered)\n");
        return out;
    }
    let width = snapshot
        .entries
        .iter()
        .map(|(k, _)| k.len())
        .max()
        .unwrap_or(0);
    for (key, value) in &snapshot.entries {
        let rendered = match value {
            MetricValue::Counter(v) => format!("{v}"),
            MetricValue::Gauge(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    format!("{}", *v as i64)
                } else {
                    format!("{v:.3}")
                }
            }
            MetricValue::Histogram(h) => {
                let (p50, p95, p99) = h.p50_p95_p99();
                format!("n={} p50={p50:?} p95={p95:?} p99={p99:?}", h.count())
            }
        };
        out.push_str(&format!("{key:<width$}  {rendered}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::MetricsRegistry;
    use crate::obs::trace::{TraceCtx, TraceSink};
    use crate::util::tempdir::TempDir;
    use std::sync::Arc;

    fn sample_events() -> Vec<TraceEvent> {
        let sink = TraceSink::new();
        let root = TraceCtx::root(Arc::clone(&sink));
        {
            let mut job = root.span("mine", "job");
            job.add("n_tx", 400.0);
            let mut task = job.ctx().span("mr", "map.task.0");
            task.add("records_read", 133.0);
        }
        sink.events()
    }

    #[test]
    fn chrome_trace_round_trips_through_the_json_parser() {
        let events = sample_events();
        let doc = chrome_trace_json(&events);
        let parsed = Json::parse(&doc.to_string()).expect("valid JSON");
        let arr = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 2);
        for ev in arr {
            assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
            assert!(ev.get("ts").and_then(Json::as_f64).is_some());
            assert!(ev.get("dur").and_then(Json::as_f64).is_some());
            let args = ev.get("args").unwrap();
            assert!(args.get("span_id").and_then(Json::as_f64).unwrap() > 0.0);
        }
        // the task span's parent is the job span
        let task = arr
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("map.task.0"))
            .unwrap();
        let job = arr
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("job"))
            .unwrap();
        assert_eq!(
            task.get("args").unwrap().get("parent_id").and_then(Json::as_f64),
            job.get("args").unwrap().get("span_id").and_then(Json::as_f64),
        );
        assert_eq!(
            task.get("args").unwrap().get("records_read").and_then(Json::as_f64),
            Some(133.0)
        );
    }

    #[test]
    fn files_are_written_and_line_parseable() {
        let tmp = TempDir::new("obs_export");
        let events = sample_events();
        let chrome = tmp.path().join("trace.json");
        let jsonl = tmp.path().join("trace.jsonl");
        write_chrome_trace(&chrome, &events).unwrap();
        write_jsonl(&jsonl, &events).unwrap();
        let doc = std::fs::read_to_string(&chrome).unwrap();
        assert!(Json::parse(&doc).is_ok());
        let lines = std::fs::read_to_string(&jsonl).unwrap();
        let mut n = 0;
        for line in lines.lines() {
            let ev = Json::parse(line).expect("each line is one JSON object");
            assert!(ev.get("span_id").and_then(Json::as_f64).is_some());
            n += 1;
        }
        assert_eq!(n, events.len());
    }

    #[test]
    fn empty_trace_exports_are_valid_documents() {
        let tmp = TempDir::new("obs_export_empty");
        let chrome = tmp.path().join("empty.json");
        let jsonl = tmp.path().join("empty.jsonl");
        write_chrome_trace(&chrome, &[]).unwrap();
        write_jsonl(&jsonl, &[]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&chrome).unwrap()).unwrap();
        let arr = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(arr.is_empty());
        assert_eq!(std::fs::read_to_string(&jsonl).unwrap(), "");
    }

    #[test]
    fn metrics_dump_is_one_line_per_instrument() {
        let reg = MetricsRegistry::new();
        reg.counter("serve.served").add(7);
        reg.gauge("mr.job.2.map_ms").set(1.25);
        reg.histogram("serve.latency")
            .record(std::time::Duration::from_millis(2));
        let text = reg.render_text();
        assert!(text.starts_with("== metrics ==\n"));
        assert_eq!(text.lines().count(), 4);
        assert!(text.contains("serve.served"));
        assert!(text.contains("7"));
        assert!(text.contains("mr.job.2.map_ms"));
        assert!(text.contains("1.250"));
        assert!(text.contains("n=1 p50="));
        let empty = MetricsRegistry::new().render_text();
        assert!(empty.contains("no instruments"));
    }
}

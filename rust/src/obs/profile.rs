//! Post-hoc trace analysis: turn a completed mine's span tree into
//! *answers* — which stage bounds the makespan, which node straggles,
//! which level's candidate blowup dominates.
//!
//! The input is the Chrome `trace_event` file `mine --trace-out` wrote
//! (parsed back through the in-tree JSON parser into [`ParsedSpan`]s) or
//! a live [`TraceSink`] buffer. [`analyze`] walks the `mine` root's span
//! tree and produces a [`MineProfile`]:
//!
//! * **stage attribution** — a sweep-line over each `level.k` window
//!   assigns every microsecond to exactly one of `map` / `shuffle` /
//!   `reduce` / `barrier_idle` (overlap resolved in that priority
//!   order); time inside the mine span but outside every level window is
//!   the `driver` stage (planning, candidate generation, DFS writes).
//!   The five stages partition the makespan, so attribution sums to
//!   100% by construction — the CI smoke asserts it.
//! * **straggler / skew detection** — per wave (the map tasks of one
//!   level, the reduce tasks of one level), the slowest task's duration
//!   against the wave median. A ratio past [`STRAGGLER_RATIO`] flags the
//!   slowest task's node; flagged nodes are cross-referenced against
//!   `cat: chaos` `fault.slow` spans so a planted `slow:N` fault shows
//!   up as a *corroborated* straggler on node N.
//! * **per-level workload statistics** — the `profile.level.k` spans the
//!   coordinator samples (density, item skew, average basket width,
//!   candidate fanout) collected per level: the calibration inputs the
//!   `perfmodel/` autotuner roadmap item consumes.
//!
//! Surfaced as `repro analyze <trace-file>` (human table or `--json`).

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

use super::trace::TraceEvent;

/// Wave tasks slower than this multiple of the wave median are flagged
/// as stragglers (Hadoop's speculative-execution heuristic uses ~1.2 on
/// progress rate; we compare completed durations, where the planted
/// chaos `slow:` factors sit well past 2).
pub const STRAGGLER_RATIO: f64 = 2.0;

/// Waves smaller than this skip straggler detection — a 2-task wave's
/// "median" is too noisy to accuse a node over.
pub const MIN_WAVE_TASKS: usize = 4;

/// A span parsed back from an exported trace file. Mirrors
/// [`TraceEvent`] but owns its `cat` (arbitrary files can't intern into
/// the `&'static str` the live sink uses).
#[derive(Debug, Clone)]
pub struct ParsedSpan {
    pub name: String,
    pub cat: String,
    pub trace_id: u64,
    pub span_id: u64,
    pub parent_id: u64,
    pub start_us: u64,
    pub dur_us: u64,
    pub args: Vec<(String, f64)>,
}

impl ParsedSpan {
    pub fn from_event(ev: &TraceEvent) -> Self {
        Self {
            name: ev.name.clone(),
            cat: ev.cat.to_string(),
            trace_id: ev.trace_id,
            span_id: ev.span_id,
            parent_id: ev.parent_id,
            start_us: ev.start_us,
            dur_us: ev.dur_us,
            args: ev.args.clone(),
        }
    }

    pub fn arg(&self, key: &str) -> Option<f64> {
        self.args
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| *v)
    }

    pub fn end_us(&self) -> u64 {
        self.start_us.saturating_add(self.dur_us)
    }
}

/// Typed analysis failure: I/O on the trace path, a garbage/truncated
/// file, or a structurally valid trace with nothing to analyze.
#[derive(Debug)]
pub enum ProfileError {
    Io(std::io::Error),
    /// The file is not a Chrome trace document (truncated write, wrong
    /// file, or malformed JSON). Carries the parser's position message.
    Parse(String),
    /// Valid trace, but no root `mine` span to attribute — e.g. a serve
    /// trace passed to `analyze`.
    NoMineRoot,
}

impl std::fmt::Display for ProfileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "trace file: {e}"),
            Self::Parse(msg) => write!(f, "not a Chrome trace: {msg}"),
            Self::NoMineRoot => write!(f, "trace has no root `mine` span to attribute"),
        }
    }
}

impl std::error::Error for ProfileError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ProfileError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

/// Parse a Chrome `trace_event` document (the `--trace-out` format) back
/// into flat spans. Only `ph: "X"` complete events are kept.
pub fn parse_chrome_trace(text: &str) -> Result<Vec<ParsedSpan>, ProfileError> {
    let doc = Json::parse(text).map_err(|e| ProfileError::Parse(e.to_string()))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| ProfileError::Parse("no traceEvents array".into()))?;
    let mut spans = Vec::with_capacity(events.len());
    for ev in events {
        if ev.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let field = |key: &str| {
            ev.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| ProfileError::Parse(format!("event missing numeric `{key}`")))
        };
        let args_obj = ev.get("args");
        let id_arg = |key: &str| {
            args_obj
                .and_then(|a| a.get(key))
                .and_then(Json::as_f64)
                .ok_or_else(|| ProfileError::Parse(format!("event args missing `{key}`")))
        };
        let mut args = Vec::new();
        if let Some(Json::Obj(map)) = args_obj {
            for (k, v) in map {
                if matches!(k.as_str(), "trace_id" | "span_id" | "parent_id") {
                    continue;
                }
                if let Some(n) = v.as_f64() {
                    args.push((k.clone(), n));
                }
            }
        }
        spans.push(ParsedSpan {
            name: ev
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| ProfileError::Parse("event missing `name`".into()))?
                .to_string(),
            cat: ev
                .get("cat")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            trace_id: id_arg("trace_id")? as u64,
            span_id: id_arg("span_id")? as u64,
            parent_id: id_arg("parent_id")? as u64,
            start_us: field("ts")? as u64,
            dur_us: field("dur")? as u64,
            args,
        });
    }
    Ok(spans)
}

/// Read and parse a `--trace-out` file.
pub fn load_chrome_trace(path: impl AsRef<Path>) -> Result<Vec<ParsedSpan>, ProfileError> {
    let text = std::fs::read_to_string(path)?;
    parse_chrome_trace(&text)
}

/// One named stage's share of the makespan.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSlice {
    pub stage: &'static str,
    pub us: u64,
    /// `us / makespan` — the five stages sum to 1.0 by construction.
    pub fraction: f64,
}

/// Straggler verdict for one wave of tasks.
#[derive(Debug, Clone)]
pub struct WaveStats {
    /// Level the wave belongs to (0 for a pipelined DAG's merged waves).
    pub k: usize,
    /// `"map"` or `"reduce"`.
    pub wave: &'static str,
    pub n_tasks: usize,
    pub median_us: u64,
    pub max_us: u64,
    /// `max_us / median_us` — duration skew across the wave.
    pub skew: f64,
    /// Node id of the slowest task (from the `node` span arg).
    pub slowest_node: Option<u64>,
    /// Skew past [`STRAGGLER_RATIO`] on a wave of at least
    /// [`MIN_WAVE_TASKS`].
    pub straggler: bool,
    /// The flagged node also appears in a `fault.slow` chaos span — the
    /// straggler is *explained*, not anomalous.
    pub chaos_slow_node: bool,
}

/// One level window's stage split (µs within the level span).
#[derive(Debug, Clone)]
pub struct LevelBreakdown {
    pub k: usize,
    pub span_us: u64,
    pub map_us: u64,
    pub shuffle_us: u64,
    pub reduce_us: u64,
    /// Level time no map/shuffle/reduce span covers: job setup, the
    /// barrier between waves, result collection.
    pub idle_us: u64,
    pub n_candidates: Option<f64>,
    pub n_frequent: Option<f64>,
}

/// Per-level workload statistics sampled by the coordinator
/// (`profile.level.k` spans) — autotuner calibration inputs.
#[derive(Debug, Clone)]
pub struct LevelWorkload {
    pub k: usize,
    /// Average fraction of the item universe present per basket.
    pub density: f64,
    /// Most-frequent-item support over mean item support.
    pub item_skew: f64,
    pub avg_basket_width: f64,
    /// `candidates(k) / frequent(k-1)` — the blowup the level paid.
    pub candidate_fanout: f64,
}

/// A chaos fault injection found in the trace, for inline context.
#[derive(Debug, Clone)]
pub struct FaultNote {
    pub name: String,
    pub node: Option<u64>,
    pub start_us: u64,
    pub args: Vec<(String, f64)>,
}

/// Everything [`analyze`] extracts from one mine trace.
#[derive(Debug, Clone)]
pub struct MineProfile {
    pub makespan_us: u64,
    /// `map` / `shuffle` / `reduce` / `barrier_idle` / `driver`, in that
    /// order; fractions sum to 1.0.
    pub stages: Vec<StageSlice>,
    pub levels: Vec<LevelBreakdown>,
    pub waves: Vec<WaveStats>,
    pub workload: Vec<LevelWorkload>,
    pub faults: Vec<FaultNote>,
}

impl MineProfile {
    /// Fraction of the makespan attributed to a named stage — 1.0 by
    /// construction; the CI smoke asserts `>= 0.95` against this.
    pub fn coverage(&self) -> f64 {
        self.stages.iter().map(|s| s.fraction).sum()
    }

    /// Nodes flagged as stragglers across all waves, deduplicated.
    pub fn straggler_nodes(&self) -> Vec<u64> {
        let mut nodes: Vec<u64> = self
            .waves
            .iter()
            .filter(|w| w.straggler)
            .filter_map(|w| w.slowest_node)
            .collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
    }
}

/// Microseconds of `[window]` covered by the union of `intervals`,
/// minus any instant already covered by a higher-priority union in
/// `claimed`. Appends its own covered segments to `claimed`.
fn sweep_claim(
    window: (u64, u64),
    intervals: &[(u64, u64)],
    claimed: &mut Vec<(u64, u64)>,
) -> u64 {
    // Elementary-segment sweep: cut the window at every boundary of
    // every interval (own + claimed), then test each segment's midpoint.
    // Span counts are small (tasks per level), so O(segments · spans)
    // is fine and avoids a fiddly interval-algebra implementation.
    let mut cuts: Vec<u64> = vec![window.0, window.1];
    for &(s, e) in intervals.iter().chain(claimed.iter()) {
        cuts.push(s.clamp(window.0, window.1));
        cuts.push(e.clamp(window.0, window.1));
    }
    cuts.sort_unstable();
    cuts.dedup();
    let mut won = 0u64;
    let mut own_segments = Vec::new();
    for pair in cuts.windows(2) {
        let (s, e) = (pair[0], pair[1]);
        if s >= e {
            continue;
        }
        let covers = |ivs: &[(u64, u64)]| ivs.iter().any(|&(a, b)| a <= s && e <= b);
        if covers(intervals) && !covers(claimed) {
            won += e - s;
            own_segments.push((s, e));
        }
    }
    claimed.extend(own_segments);
    won
}

fn spans_of<'a>(
    spans: &'a [ParsedSpan],
    parent: u64,
    prefix: &str,
) -> Vec<&'a ParsedSpan> {
    spans
        .iter()
        .filter(|s| s.parent_id == parent && s.name.starts_with(prefix))
        .collect()
}

fn wave_stats(
    k: usize,
    wave: &'static str,
    tasks: &[&ParsedSpan],
    slow_nodes: &[u64],
) -> Option<WaveStats> {
    if tasks.is_empty() {
        return None;
    }
    let mut durs: Vec<(u64, Option<u64>)> = tasks
        .iter()
        .map(|t| (t.dur_us, t.arg("node").map(|n| n as u64)))
        .collect();
    durs.sort_unstable_by_key(|(d, _)| *d);
    let median_us = durs[durs.len() / 2].0;
    let &(max_us, slowest_node) = durs.last().expect("non-empty wave");
    let skew = max_us as f64 / median_us.max(1) as f64;
    let straggler = durs.len() >= MIN_WAVE_TASKS && skew >= STRAGGLER_RATIO;
    let chaos_slow_node =
        straggler && slowest_node.is_some_and(|n| slow_nodes.contains(&n));
    Some(WaveStats {
        k,
        wave,
        n_tasks: durs.len(),
        median_us,
        max_us,
        skew,
        slowest_node,
        straggler,
        chaos_slow_node,
    })
}

/// Analyze one mine's spans (parsed from a trace file or converted from
/// a live sink via [`ParsedSpan::from_event`]).
pub fn analyze(spans: &[ParsedSpan]) -> Result<MineProfile, ProfileError> {
    let mine = spans
        .iter()
        .filter(|s| s.cat == "mine" && s.name == "mine" && s.parent_id == 0)
        .max_by_key(|s| s.dur_us)
        .ok_or(ProfileError::NoMineRoot)?;
    let makespan_us = mine.dur_us.max(1);
    let window_of = |s: &ParsedSpan| {
        (
            s.start_us.clamp(mine.start_us, mine.end_us()),
            s.end_us().clamp(mine.start_us, mine.end_us()),
        )
    };

    // Chaos fault spans are roots of their own (the clock outlives any
    // single mine), so collect them sink-wide for cross-referencing.
    let faults: Vec<FaultNote> = spans
        .iter()
        .filter(|s| s.cat == "chaos")
        .map(|s| FaultNote {
            name: s.name.clone(),
            node: s.arg("node").map(|n| n as u64),
            start_us: s.start_us,
            args: s.args.clone(),
        })
        .collect();
    let slow_nodes: Vec<u64> = faults
        .iter()
        .filter(|f| f.name == "fault.slow")
        .filter_map(|f| f.node)
        .collect();

    // Level windows under the mine root. A pipelined DAG attaches tasks
    // directly to the root; treat the whole mine window as one merged
    // "level 0" so attribution still partitions the makespan.
    let synthetic_root = ParsedSpan {
        name: "level.0".into(),
        ..mine.clone()
    };
    let mut level_spans: Vec<&ParsedSpan> = spans_of(spans, mine.span_id, "level.");
    level_spans.sort_by_key(|s| s.start_us);
    let merged_dag = level_spans.is_empty();
    if merged_dag {
        level_spans.push(&synthetic_root);
    }

    let mut levels = Vec::new();
    let mut waves = Vec::new();
    let mut workload = Vec::new();
    let (mut map_total, mut shuffle_total, mut reduce_total, mut idle_total) =
        (0u64, 0u64, 0u64, 0u64);
    let mut level_union: Vec<(u64, u64)> = Vec::new();

    for level in &level_spans {
        let k = level
            .name
            .strip_prefix("level.")
            .and_then(|k| k.parse::<usize>().ok())
            .unwrap_or(0);
        // Tasks parent to the level span synchronously, to the mine root
        // in the pipelined DAG.
        let task_parent = if merged_dag { mine.span_id } else { level.span_id };
        let maps = spans_of(spans, task_parent, "map.task.");
        let reduces = spans_of(spans, task_parent, "reduce.task.");
        let shuffles = spans_of(spans, task_parent, "shuffle");

        let window = window_of(level);
        let span_us = window.1 - window.0;
        // Priority map > shuffle > reduce: an instant covered by several
        // stages (pipelined overlap, shuffle running under late maps)
        // counts once, for the earliest stage.
        let mut claimed = Vec::new();
        let ivs = |ss: &[&ParsedSpan]| -> Vec<(u64, u64)> {
            ss.iter().map(|s| (s.start_us, s.end_us())).collect()
        };
        let map_us = sweep_claim(window, &ivs(&maps), &mut claimed);
        let shuffle_us = sweep_claim(window, &ivs(&shuffles), &mut claimed);
        let reduce_us = sweep_claim(window, &ivs(&reduces), &mut claimed);
        let idle_us = span_us.saturating_sub(map_us + shuffle_us + reduce_us);
        map_total += map_us;
        shuffle_total += shuffle_us;
        reduce_total += reduce_us;
        idle_total += idle_us;
        level_union.push(window);

        waves.extend(wave_stats(k, "map", &maps, &slow_nodes));
        waves.extend(wave_stats(k, "reduce", &reduces, &slow_nodes));

        for p in spans
            .iter()
            .filter(|s| s.cat == "profile" && s.parent_id == level.span_id)
        {
            workload.push(LevelWorkload {
                k,
                density: p.arg("density").unwrap_or(0.0),
                item_skew: p.arg("item_skew").unwrap_or(0.0),
                avg_basket_width: p.arg("avg_basket_width").unwrap_or(0.0),
                candidate_fanout: p.arg("candidate_fanout").unwrap_or(0.0),
            });
        }

        levels.push(LevelBreakdown {
            k,
            span_us,
            map_us,
            shuffle_us,
            reduce_us,
            idle_us,
            n_candidates: level.arg("candidates"),
            n_frequent: level.arg("frequent"),
        });
    }

    // Driver stage: mine time outside every level window (planning,
    // candidate generation, DFS writes, result collection).
    let mut claimed = Vec::new();
    let covered = sweep_claim((mine.start_us, mine.end_us()), &level_union, &mut claimed);
    let driver_us = makespan_us.saturating_sub(covered);

    let slice = |stage: &'static str, us: u64| StageSlice {
        stage,
        us,
        fraction: us as f64 / makespan_us as f64,
    };
    let stages = vec![
        slice("map", map_total),
        slice("shuffle", shuffle_total),
        slice("reduce", reduce_total),
        slice("barrier_idle", idle_total),
        slice("driver", driver_us),
    ];

    Ok(MineProfile {
        makespan_us,
        stages,
        levels,
        waves,
        workload,
        faults,
    })
}

/// Convenience: load, parse, analyze.
pub fn analyze_file(path: impl AsRef<Path>) -> Result<MineProfile, ProfileError> {
    analyze(&load_chrome_trace(path)?)
}

fn ms(us: u64) -> f64 {
    us as f64 / 1e3
}

/// The human-readable attribution table `repro analyze` prints.
pub fn render_table(p: &MineProfile) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== analyze: makespan {:.3} ms, {:.1}% attributed ==",
        ms(p.makespan_us),
        p.coverage() * 100.0
    );
    let _ = writeln!(out, "{:<14} {:>12} {:>8}", "stage", "time_ms", "share");
    for s in &p.stages {
        let _ = writeln!(
            out,
            "{:<14} {:>12.3} {:>7.1}%",
            s.stage,
            ms(s.us),
            s.fraction * 100.0
        );
    }
    let _ = writeln!(
        out,
        "\n{:<7} {:>10} {:>7} {:>9} {:>8} {:>7} {:>11}",
        "level", "span_ms", "map%", "shuffle%", "reduce%", "idle%", "candidates"
    );
    for l in &p.levels {
        let pct = |us: u64| 100.0 * us as f64 / l.span_us.max(1) as f64;
        let _ = writeln!(
            out,
            "{:<7} {:>10.3} {:>6.1}% {:>8.1}% {:>7.1}% {:>6.1}% {:>11}",
            l.k,
            ms(l.span_us),
            pct(l.map_us),
            pct(l.shuffle_us),
            pct(l.reduce_us),
            pct(l.idle_us),
            l.n_candidates.map_or_else(|| "-".into(), |c| format!("{c:.0}")),
        );
    }
    let stragglers: Vec<&WaveStats> = p.waves.iter().filter(|w| w.straggler).collect();
    if stragglers.is_empty() {
        let _ = writeln!(out, "\nstragglers: none (all waves under {STRAGGLER_RATIO}x median)");
    } else {
        let _ = writeln!(out, "\nstragglers:");
        for w in stragglers {
            let _ = writeln!(
                out,
                "  level {} {} wave: node {} slowest ({:.1}x median over {} tasks){}",
                w.k,
                w.wave,
                w.slowest_node.map_or_else(|| "?".into(), |n| n.to_string()),
                w.skew,
                w.n_tasks,
                if w.chaos_slow_node {
                    " — matches injected slow: fault"
                } else {
                    ""
                }
            );
        }
    }
    if !p.faults.is_empty() {
        let _ = writeln!(out, "\nfaults:");
        for f in &p.faults {
            let _ = writeln!(
                out,
                "  {} node={} @ {:.3} ms",
                f.name,
                f.node.map_or_else(|| "-".into(), |n| n.to_string()),
                ms(f.start_us)
            );
        }
    }
    if !p.workload.is_empty() {
        let _ = writeln!(
            out,
            "\n{:<7} {:>9} {:>10} {:>13} {:>10}",
            "level", "density", "item_skew", "basket_width", "fanout"
        );
        for w in &p.workload {
            let _ = writeln!(
                out,
                "{:<7} {:>9.4} {:>10.2} {:>13.2} {:>10.2}",
                w.k, w.density, w.item_skew, w.avg_basket_width, w.candidate_fanout
            );
        }
    }
    out
}

/// The machine-readable form (`repro analyze --json`).
pub fn to_json(p: &MineProfile) -> Json {
    let stage = |s: &StageSlice| {
        Json::obj(vec![
            ("stage", Json::str(s.stage)),
            ("us", Json::num(s.us as f64)),
            ("fraction", Json::num(s.fraction)),
        ])
    };
    let level = |l: &LevelBreakdown| {
        Json::obj(vec![
            ("k", Json::num(l.k as f64)),
            ("span_us", Json::num(l.span_us as f64)),
            ("map_us", Json::num(l.map_us as f64)),
            ("shuffle_us", Json::num(l.shuffle_us as f64)),
            ("reduce_us", Json::num(l.reduce_us as f64)),
            ("idle_us", Json::num(l.idle_us as f64)),
        ])
    };
    let wave = |w: &WaveStats| {
        Json::obj(vec![
            ("k", Json::num(w.k as f64)),
            ("wave", Json::str(w.wave)),
            ("n_tasks", Json::num(w.n_tasks as f64)),
            ("median_us", Json::num(w.median_us as f64)),
            ("max_us", Json::num(w.max_us as f64)),
            ("skew", Json::num(w.skew)),
            (
                "slowest_node",
                w.slowest_node.map_or(Json::Null, |n| Json::num(n as f64)),
            ),
            ("straggler", Json::Bool(w.straggler)),
            ("chaos_slow_node", Json::Bool(w.chaos_slow_node)),
        ])
    };
    let load = |w: &LevelWorkload| {
        Json::obj(vec![
            ("k", Json::num(w.k as f64)),
            ("density", Json::num(w.density)),
            ("item_skew", Json::num(w.item_skew)),
            ("avg_basket_width", Json::num(w.avg_basket_width)),
            ("candidate_fanout", Json::num(w.candidate_fanout)),
        ])
    };
    let fault = |f: &FaultNote| {
        let mut fields = vec![
            ("name", Json::str(f.name.clone())),
            ("start_us", Json::num(f.start_us as f64)),
            (
                "node",
                f.node.map_or(Json::Null, |n| Json::num(n as f64)),
            ),
        ];
        let args: BTreeMap<String, Json> = f
            .args
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(*v)))
            .collect();
        fields.push(("args", Json::Obj(args)));
        Json::obj(fields)
    };
    Json::obj(vec![
        ("makespan_us", Json::num(p.makespan_us as f64)),
        ("coverage", Json::num(p.coverage())),
        ("stages", Json::Arr(p.stages.iter().map(stage).collect())),
        ("levels", Json::Arr(p.levels.iter().map(level).collect())),
        ("waves", Json::Arr(p.waves.iter().map(wave).collect())),
        ("workload", Json::Arr(p.workload.iter().map(load).collect())),
        ("faults", Json::Arr(p.faults.iter().map(fault).collect())),
        (
            "straggler_nodes",
            Json::Arr(
                p.straggler_nodes()
                    .iter()
                    .map(|&n| Json::num(n as f64))
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(
        name: &str,
        cat: &str,
        span_id: u64,
        parent_id: u64,
        start_us: u64,
        dur_us: u64,
        args: &[(&str, f64)],
    ) -> ParsedSpan {
        ParsedSpan {
            name: name.into(),
            cat: cat.into(),
            trace_id: 1,
            span_id,
            parent_id,
            start_us,
            dur_us,
            args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    /// A hand-built two-level mine: level windows inside the mine span,
    /// task waves inside the levels, known gaps for idle/driver time.
    fn synthetic_mine() -> Vec<ParsedSpan> {
        let mut spans = vec![span("mine", "mine", 1, 0, 0, 1000, &[])];
        // level 1: [100, 400); maps union [100,240), shuffle [250,300),
        // reduce [300,380)
        spans.push(span("level.1", "mine", 2, 1, 100, 300, &[("candidates", 8.0)]));
        for t in 0..4u64 {
            spans.push(span(
                &format!("map.task.{t}"),
                "mr",
                10 + t,
                2,
                100 + t * 10,
                110,
                &[("node", t as f64 % 2.0)],
            ));
        }
        spans.push(span("shuffle", "mr", 20, 2, 250, 50, &[]));
        spans.push(span("reduce.task.0", "mr", 21, 2, 300, 80, &[("node", 0.0)]));
        // level 2: [500, 900) with a planted straggler on node 1
        spans.push(span("level.2", "mine", 3, 1, 500, 400, &[("candidates", 5.0)]));
        for t in 0..4u64 {
            let (dur, node) = if t == 3 { (390, 1.0) } else { (80, 0.0) };
            spans.push(span(
                &format!("map.task.{t}"),
                "mr",
                30 + t,
                3,
                500,
                dur,
                &[("node", node)],
            ));
        }
        spans.push(span(
            "profile.level.2",
            "profile",
            40,
            3,
            500,
            1,
            &[
                ("density", 0.25),
                ("item_skew", 3.0),
                ("avg_basket_width", 10.0),
                ("candidate_fanout", 1.5),
            ],
        ));
        spans
    }

    #[test]
    fn attribution_partitions_the_makespan() {
        let profile = analyze(&synthetic_mine()).unwrap();
        assert_eq!(profile.makespan_us, 1000);
        let total: u64 = profile.stages.iter().map(|s| s.us).sum();
        assert_eq!(total, 1000, "stages must partition the makespan exactly");
        assert!((profile.coverage() - 1.0).abs() < 1e-9);
        // known geometry: driver = [0,100) + [400,500) + [900,1000)
        let get = |name: &str| {
            profile
                .stages
                .iter()
                .find(|s| s.stage == name)
                .unwrap()
                .us
        };
        assert_eq!(get("driver"), 300);
        // level 1's staggered maps union to [100,240), level 2's to
        // [500,890) (the straggler stretches the wave)
        assert_eq!(get("map"), 140 + 390);
        assert_eq!(get("shuffle"), 50);
        assert_eq!(get("reduce"), 80);
        assert_eq!(get("barrier_idle"), 1000 - 300 - 530 - 50 - 80);
    }

    #[test]
    fn straggler_flagged_on_the_slow_node_and_chaos_corroborated() {
        let mut spans = synthetic_mine();
        // no chaos span yet: straggler flagged but not corroborated
        let p = analyze(&spans).unwrap();
        let wave = p
            .waves
            .iter()
            .find(|w| w.k == 2 && w.wave == "map")
            .unwrap();
        assert!(wave.straggler, "4.9x median must flag");
        assert_eq!(wave.slowest_node, Some(1));
        assert!(!wave.chaos_slow_node);
        assert_eq!(p.straggler_nodes(), vec![1]);
        // level 1's tight wave must NOT flag
        let tight = p
            .waves
            .iter()
            .find(|w| w.k == 1 && w.wave == "map")
            .unwrap();
        assert!(!tight.straggler);

        spans.push(span(
            "fault.slow",
            "chaos",
            90,
            0,
            0,
            1,
            &[("node", 1.0), ("factor", 3.0)],
        ));
        let p = analyze(&spans).unwrap();
        let wave = p
            .waves
            .iter()
            .find(|w| w.k == 2 && w.wave == "map")
            .unwrap();
        assert!(wave.chaos_slow_node, "slow: fault on node 1 corroborates");
        assert_eq!(p.faults.len(), 1);
    }

    #[test]
    fn workload_stats_are_collected_per_level() {
        let p = analyze(&synthetic_mine()).unwrap();
        assert_eq!(p.workload.len(), 1);
        let w = &p.workload[0];
        assert_eq!(w.k, 2);
        assert!((w.density - 0.25).abs() < 1e-9);
        assert!((w.candidate_fanout - 1.5).abs() < 1e-9);
    }

    #[test]
    fn chrome_roundtrip_then_analyze() {
        use crate::obs::trace::{TraceCtx, TraceSink};
        use std::sync::Arc;
        let sink = TraceSink::new();
        let root = TraceCtx::root(Arc::clone(&sink));
        {
            let mine = root.span("mine", "mine");
            {
                let level = mine.ctx().span("mine", "level.1");
                for t in 0..4 {
                    let mut task = level.ctx().span("mr", format!("map.task.{t}"));
                    task.add("node", (t % 2) as f64);
                }
            }
        }
        let doc = crate::obs::export::chrome_trace_json(&sink.events());
        let spans = parse_chrome_trace(&doc.to_string()).unwrap();
        assert_eq!(spans.len(), 6);
        let p = analyze(&spans).unwrap();
        assert!((p.coverage() - 1.0).abs() < 1e-9);
        assert_eq!(p.levels.len(), 1);
        // table + json render without panicking and carry the headline
        let table = render_table(&p);
        assert!(table.contains("makespan"));
        let json = to_json(&p);
        assert!(json.get("coverage").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn garbage_and_truncated_input_is_a_typed_parse_error() {
        assert!(matches!(
            parse_chrome_trace("not json at all"),
            Err(ProfileError::Parse(_))
        ));
        // a real document, truncated mid-write
        let doc = r#"{"traceEvents": [{"name": "mine", "cat": "mine", "ph":"#;
        assert!(matches!(
            parse_chrome_trace(doc),
            Err(ProfileError::Parse(_))
        ));
        // valid JSON, wrong shape
        assert!(matches!(
            parse_chrome_trace(r#"{"hello": 1}"#),
            Err(ProfileError::Parse(_))
        ));
        // valid trace, nothing to analyze
        assert!(matches!(
            analyze(&[]),
            Err(ProfileError::NoMineRoot)
        ));
    }

    #[test]
    fn pipelined_trace_without_level_spans_still_partitions() {
        // tasks attach straight to the mine root (the job-DAG shape)
        let mut spans = vec![span("mine", "mine", 1, 0, 0, 500, &[])];
        for t in 0..4u64 {
            spans.push(span(
                &format!("map.task.{t}"),
                "mr",
                10 + t,
                1,
                50 + t * 50,
                100,
                &[("node", t as f64)],
            ));
        }
        spans.push(span("reduce.task.0", "mr", 20, 1, 300, 100, &[]));
        let p = analyze(&spans).unwrap();
        assert!((p.coverage() - 1.0).abs() < 1e-9);
        assert_eq!(p.levels.len(), 1);
        assert_eq!(p.levels[0].k, 0);
        let total: u64 = p.stages.iter().map(|s| s.us).sum();
        assert_eq!(total, 500);
    }
}

//! Serve-side SLO tracking: a p99 latency target checked over a sliding
//! burn-rate window of the existing latency histograms.
//!
//! The `[slo]` config names a p99 target; the watcher polls the server's
//! user-lane histogram every `window_ms`, diffs consecutive snapshots
//! ([`HistogramSnapshot::diff`] — the same per-phase mechanism the
//! benches use), and evaluates each window in isolation:
//!
//! * **breach** — the window's p99 exceeds the target (only windows with
//!   at least `min_requests` count, so an idle server's single slow
//!   request can't page anyone);
//! * **burn rate** — the fraction of the window's requests over the
//!   target divided by the SLO's error budget (1 − 0.99): burn 1.0 means
//!   exactly on budget, 2.0 means burning it twice as fast.
//!
//! Breaches log at `Warn`, bump the `slo.*` counters, and (when a
//! [`FlightRecorder`] is attached) trigger an incident dump — the last
//! few thousand request spans plus the metrics cut at breach time.
//!
//! [`FlightRecorder`]: super::flight::FlightRecorder

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::metrics::histogram::{HistogramSnapshot, LatencyHistogram};

use super::registry::MetricsRegistry;

/// The `[slo]` config section (`--slo-p99-ms` and friends override it).
#[derive(Debug, Clone, PartialEq)]
pub struct SloConfig {
    /// p99 latency target in milliseconds; 0 disables the watcher.
    pub p99_ms: f64,
    /// Evaluation window.
    pub window_ms: u64,
    /// Windows with fewer requests than this are skipped (not judged).
    pub min_requests: u64,
}

impl Default for SloConfig {
    fn default() -> Self {
        Self {
            p99_ms: 0.0,
            window_ms: 1_000,
            min_requests: 50,
        }
    }
}

impl SloConfig {
    pub fn enabled(&self) -> bool {
        self.p99_ms > 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.p99_ms < 0.0 || !self.p99_ms.is_finite() {
            return Err(format!("slo.p99_ms must be finite and >= 0, got {}", self.p99_ms));
        }
        if self.window_ms == 0 {
            return Err("slo.window_ms must be > 0".into());
        }
        Ok(())
    }

    pub fn target(&self) -> Duration {
        Duration::from_nanos((self.p99_ms * 1e6) as u64)
    }
}

/// One evaluated window's verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct SloVerdict {
    /// Requests the window saw.
    pub requests: u64,
    /// The window's p99.
    pub p99: Duration,
    pub breached: bool,
    /// Error-budget burn rate: fraction of requests over target / 0.01.
    pub burn_rate: f64,
}

/// Watches one latency histogram against one [`SloConfig`]. The
/// evaluation step is pure state-machine ([`Self::evaluate`] — cover it
/// in tests without sleeping); `main.rs` owns the polling thread.
pub struct SloWatcher {
    cfg: SloConfig,
    histogram: Arc<LatencyHistogram>,
    last: Mutex<HistogramSnapshot>,
    registry: Option<Arc<MetricsRegistry>>,
}

impl SloWatcher {
    pub fn new(cfg: SloConfig, histogram: Arc<LatencyHistogram>) -> Self {
        let last = Mutex::new(histogram.snapshot());
        Self { cfg, histogram, last, registry: None }
    }

    /// Publish `slo.windows`, `slo.breach`, `slo.burn_rate` under
    /// `registry` (counters cumulative, burn rate a last-window gauge).
    /// Get-or-create semantics: the keys are namespaced to this watcher,
    /// so the instruments exist (at zero) before the first window closes.
    pub fn register_metrics(mut self, registry: &Arc<MetricsRegistry>) -> Self {
        registry.counter("slo.windows");
        registry.counter("slo.breach");
        registry.gauge("slo.burn_rate");
        self.registry = Some(Arc::clone(registry));
        self
    }

    pub fn config(&self) -> &SloConfig {
        &self.cfg
    }

    /// Close the current window and judge it: diff the histogram against
    /// the previous snapshot, apply the `min_requests` floor, compare
    /// p99 to target. Returns `None` for skipped (under-traffic)
    /// windows. Call once per `window_ms` tick.
    pub fn evaluate(&self) -> Option<SloVerdict> {
        let now = self.histogram.snapshot();
        let window = {
            let mut last = self.last.lock().unwrap();
            let window = now.diff(&last);
            *last = now;
            window
        };
        let requests = window.count();
        if requests < self.cfg.min_requests.max(1) {
            return None;
        }
        let p99 = window.quantile(0.99);
        let target = self.cfg.target();
        let over = window.fraction_above(target);
        let verdict = SloVerdict {
            requests,
            p99,
            breached: p99 > target,
            burn_rate: over / 0.01,
        };
        if let Some(reg) = &self.registry {
            reg.counter("slo.windows").inc();
            reg.gauge("slo.burn_rate").set(verdict.burn_rate);
            if verdict.breached {
                reg.counter("slo.breach").inc();
            }
        }
        Some(verdict)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn watcher(p99_ms: f64, min_requests: u64) -> SloWatcher {
        let cfg = SloConfig { p99_ms, min_requests, ..Default::default() };
        SloWatcher::new(cfg, Arc::new(LatencyHistogram::new()))
    }

    #[test]
    fn config_validates_and_gates() {
        assert!(!SloConfig::default().enabled());
        assert!(SloConfig::default().validate().is_ok());
        let on = SloConfig { p99_ms: 5.0, ..Default::default() };
        assert!(on.enabled());
        assert!(on.validate().is_ok());
        assert!(SloConfig { p99_ms: -1.0, ..Default::default() }.validate().is_err());
        assert!(SloConfig { window_ms: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn quiet_window_is_skipped_not_judged() {
        let w = watcher(1.0, 50);
        assert_eq!(w.evaluate(), None);
        for _ in 0..49 {
            w.histogram.record(Duration::from_millis(100));
        }
        assert_eq!(w.evaluate(), None, "49 slow requests stay under the floor");
    }

    #[test]
    fn breach_and_burn_rate_over_one_window() {
        let w = watcher(1.0, 10);
        // 95 fast + 5 slow: p99 lands in the slow mode, 5% over target
        for _ in 0..95 {
            w.histogram.record(Duration::from_micros(100));
        }
        for _ in 0..5 {
            w.histogram.record(Duration::from_millis(50));
        }
        let v = w.evaluate().expect("enough traffic");
        assert_eq!(v.requests, 100);
        assert!(v.breached, "p99 {:?} must exceed 1ms", v.p99);
        assert!(
            (4.0..=6.5).contains(&v.burn_rate),
            "5% over a 1% budget burns ~5x, got {}",
            v.burn_rate
        );

        // the next window starts clean: all-fast traffic passes
        for _ in 0..100 {
            w.histogram.record(Duration::from_micros(100));
        }
        let v = w.evaluate().expect("enough traffic");
        assert!(!v.breached, "windows are independent (diff semantics)");
        assert_eq!(v.burn_rate, 0.0);
    }

    #[test]
    fn counters_accumulate_across_windows() {
        let reg = Arc::new(crate::obs::MetricsRegistry::new());
        let w = watcher(1.0, 1).register_metrics(&reg);
        for _ in 0..10 {
            w.histogram.record(Duration::from_millis(10));
        }
        assert!(w.evaluate().unwrap().breached);
        for _ in 0..10 {
            w.histogram.record(Duration::from_micros(10));
        }
        assert!(!w.evaluate().unwrap().breached);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("slo.windows"), Some(2));
        assert_eq!(snap.counter("slo.breach"), Some(1));
        assert_eq!(snap.gauge("slo.burn_rate"), Some(0.0));
    }
}

//! The flight recorder: a bounded ring of the most recent spans plus a
//! coherent metrics cut, dumped to disk when something goes wrong.
//!
//! Post-mortem tracing has a cost problem: a long serve run records
//! millions of spans, but the interesting ones are always the last few
//! thousand before the incident. The recorder tees every span the
//! [`TraceSink`] records into a fixed-capacity ring (old spans
//! overwritten, never reallocated), and [`FlightRecorder::dump`] writes
//! the ring — as a normal Chrome trace document, loadable in Perfetto
//! and parseable by `repro analyze` — together with a metrics snapshot
//! and the trigger reason, into `--flight-dir`. Triggers wired in
//! `main.rs`: a mine job error, chaos kill-fault escalation, and a serve
//! SLO breach ([`super::slo`]).
//!
//! [`TraceSink`]: super::trace::TraceSink

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

use super::export::chrome_trace_json;
use super::registry::{MetricValue, MetricsSnapshot};
use super::trace::TraceEvent;

/// Default ring capacity — enough for the full map/reduce task tree of
/// several mine levels or a few thousand serve requests, at roughly
/// 100 bytes a span.
pub const DEFAULT_CAPACITY: usize = 4096;

#[derive(Debug)]
struct Ring {
    /// Storage; grows to `capacity` then holds.
    slots: Vec<TraceEvent>,
    /// Next slot to overwrite once the ring is full.
    next: usize,
}

/// The bounded span ring + dump machinery. One per process, attached to
/// the trace sink with [`TraceSink::attach_flight`]; `observe` is called
/// from the sink's record path, everything else from trigger sites.
///
/// [`TraceSink::attach_flight`]: super::trace::TraceSink::attach_flight
#[derive(Debug)]
pub struct FlightRecorder {
    capacity: usize,
    dir: PathBuf,
    ring: Mutex<Ring>,
    /// Spans ever observed (`>= capacity` means the ring wrapped).
    observed: AtomicU64,
    /// Dump file sequence number, so repeated triggers never clobber.
    dumps: AtomicU64,
}

impl FlightRecorder {
    #[allow(clippy::new_ret_no_self)]
    pub fn new(dir: impl Into<PathBuf>, capacity: usize) -> Arc<Self> {
        Arc::new(Self {
            capacity: capacity.max(1),
            dir: dir.into(),
            ring: Mutex::new(Ring { slots: Vec::new(), next: 0 }),
            observed: AtomicU64::new(0),
            dumps: AtomicU64::new(0),
        })
    }

    /// Tee one completed span into the ring (called by the sink under
    /// its own record path; the ring lock is held only for the copy).
    pub fn observe(&self, event: &TraceEvent) {
        let mut ring = self.ring.lock().unwrap();
        if ring.slots.len() < self.capacity {
            ring.slots.push(event.clone());
        } else {
            let next = ring.next;
            ring.slots[next] = event.clone();
            ring.next = (next + 1) % self.capacity;
        }
        self.observed.fetch_add(1, Ordering::Relaxed);
    }

    /// Spans ever observed (kept spans = `min(observed, capacity)`).
    pub fn observed(&self) -> u64 {
        self.observed.load(Ordering::Relaxed)
    }

    /// The retained window, oldest first.
    pub fn recent(&self) -> Vec<TraceEvent> {
        let ring = self.ring.lock().unwrap();
        let mut out = Vec::with_capacity(ring.slots.len());
        out.extend_from_slice(&ring.slots[ring.next..]);
        out.extend_from_slice(&ring.slots[..ring.next]);
        out
    }

    /// Dump the ring + a metrics cut to `<dir>/flight-<seq>-<reason>.json`
    /// and return the path. The document's `trace` field is a complete
    /// Chrome trace (Perfetto-loadable after extraction); `metrics` maps
    /// dotted keys to values with histograms as `{count,p50,p95,p99}`.
    pub fn dump(
        &self,
        reason: &str,
        metrics: Option<&MetricsSnapshot>,
    ) -> io::Result<PathBuf> {
        let events = self.recent();
        let seq = self.dumps.fetch_add(1, Ordering::Relaxed);
        let slug: String = reason
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
            .take(48)
            .collect();
        std::fs::create_dir_all(&self.dir)?;
        let path = self.dir.join(format!("flight-{seq:03}-{slug}.json"));
        let doc = Json::obj(vec![
            ("reason", Json::str(reason)),
            ("spans_retained", Json::num(events.len() as f64)),
            ("spans_observed", Json::num(self.observed() as f64)),
            ("trace", chrome_trace_json(&events)),
            (
                "metrics",
                metrics.map_or(Json::Null, render_metrics_json),
            ),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        Ok(path)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// A metrics cut as JSON: counters and gauges as numbers, histograms as
/// their count + tail quantiles (the full bucket vector is overkill for
/// an incident file).
fn render_metrics_json(snapshot: &MetricsSnapshot) -> Json {
    let mut fields = Vec::with_capacity(snapshot.entries.len());
    for (key, value) in &snapshot.entries {
        let v = match value {
            MetricValue::Counter(v) => Json::num(*v as f64),
            MetricValue::Gauge(v) => Json::num(*v),
            MetricValue::Histogram(h) => {
                let (p50, p95, p99) = h.p50_p95_p99();
                Json::obj(vec![
                    ("count", Json::num(h.count() as f64)),
                    ("p50_us", Json::num(p50.as_micros() as f64)),
                    ("p95_us", Json::num(p95.as_micros() as f64)),
                    ("p99_us", Json::num(p99.as_micros() as f64)),
                ])
            }
        };
        fields.push((key.as_str(), v));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::MetricsRegistry;
    use crate::obs::trace::{TraceCtx, TraceSink};
    use crate::util::tempdir::TempDir;

    fn event(name: &str, span_id: u64) -> TraceEvent {
        TraceEvent {
            name: name.into(),
            cat: "mr",
            trace_id: 1,
            span_id,
            parent_id: 0,
            start_us: span_id,
            dur_us: 1,
            tid: 1,
            args: Vec::new(),
        }
    }

    #[test]
    fn ring_wraps_keeping_the_newest_spans_in_order() {
        let tmp = TempDir::new("flight_wrap");
        let rec = FlightRecorder::new(tmp.path(), 4);
        for i in 0..10u64 {
            rec.observe(&event(&format!("s{i}"), i + 1));
        }
        assert_eq!(rec.observed(), 10);
        let kept = rec.recent();
        assert_eq!(kept.len(), 4, "ring holds exactly its capacity");
        let names: Vec<&str> = kept.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["s6", "s7", "s8", "s9"], "oldest first");
    }

    #[test]
    fn under_capacity_nothing_is_dropped() {
        let tmp = TempDir::new("flight_small");
        let rec = FlightRecorder::new(tmp.path(), 100);
        for i in 0..3u64 {
            rec.observe(&event(&format!("s{i}"), i + 1));
        }
        let names: Vec<String> = rec.recent().iter().map(|e| e.name.clone()).collect();
        assert_eq!(names, vec!["s0", "s1", "s2"]);
    }

    #[test]
    fn dump_writes_a_parseable_incident_file() {
        let tmp = TempDir::new("flight_dump");
        let rec = FlightRecorder::new(tmp.path().join("flights"), 8);
        for i in 0..12u64 {
            rec.observe(&event(&format!("s{i}"), i + 1));
        }
        let reg = MetricsRegistry::new();
        reg.counter("slo.breach").inc();
        reg.histogram("serve.latency")
            .record(std::time::Duration::from_millis(3));
        let path = rec.dump("slo breach: p99", Some(&reg.snapshot())).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap().starts_with("flight-000-"));
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("reason").and_then(Json::as_str), Some("slo breach: p99"));
        assert_eq!(doc.get("spans_retained").and_then(Json::as_f64), Some(8.0));
        assert_eq!(doc.get("spans_observed").and_then(Json::as_f64), Some(12.0));
        // the embedded trace is itself analyzable Chrome format
        let trace = doc.get("trace").unwrap();
        let events = trace.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), 8);
        let metrics = doc.get("metrics").unwrap();
        assert_eq!(metrics.get("slo.breach").and_then(Json::as_f64), Some(1.0));
        assert!(metrics.get("serve.latency").unwrap().get("p99_us").is_some());
        // a second dump gets a fresh sequence number
        let path2 = rec.dump("again", None).unwrap();
        assert_ne!(path, path2);
    }

    #[test]
    fn sink_tee_feeds_the_recorder() {
        let tmp = TempDir::new("flight_tee");
        let sink = TraceSink::new();
        let rec = FlightRecorder::new(tmp.path(), 4);
        sink.attach_flight(Arc::clone(&rec));
        let root = TraceCtx::root(Arc::clone(&sink));
        for i in 0..6 {
            let _span = root.span("serve", format!("req.{i}"));
        }
        assert_eq!(sink.len(), 6, "sink keeps everything");
        assert_eq!(rec.observed(), 6);
        assert_eq!(rec.recent().len(), 4, "recorder keeps the window");
    }
}

//! A process-wide metrics registry: named counters, gauges, and latency
//! histograms under hierarchical dotted keys, snapshot-able as one
//! coherent cut.
//!
//! The registry does not own a global singleton — each command (`mine`,
//! `serve`, a test) constructs its own [`MetricsRegistry`] and hands it
//! to the subsystems it wires together. Components keep their hot-path
//! instruments as plain `Arc<Counter>` / `Arc<LatencyHistogram>` fields
//! (lock-free increments, exactly as before) and *register* those arcs
//! under stable keys; the registry is only locked to register, to
//! enumerate, and to snapshot. A snapshot reads every instrument under a
//! single lock acquisition, so no registration can interleave with the
//! cut — the "no torn cut" contract `tests/obs.rs` pins.
//!
//! Key naming scheme (see DESIGN.md §Observability): lowercase dotted
//! hierarchy, subsystem first — `mr.job.3.map_ms`, `engine.cache.hits`,
//! `serve.served`, `fabric.router.hedge_wins`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::histogram::{HistogramSnapshot, LatencyHistogram};
use crate::metrics::Counter;

/// A last-value instrument for sampled quantities (resident bytes, the
/// current generation, a phase's wall-clock). Stores `f64` bits in an
/// atomic, so `set`/`get` are wait-free like [`Counter`].
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&self, value: f64) {
        self.0.store(value.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// One registered instrument. Shared ownership: the component keeps one
/// arc for its hot path, the registry keeps the other for snapshots.
#[derive(Debug, Clone)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<LatencyHistogram>),
}

/// The value of one instrument inside a [`MetricsSnapshot`].
#[derive(Debug, Clone)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histogram(HistogramSnapshot),
}

/// Typed registration failure: every key names exactly one instrument.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    DuplicateKey { key: String },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::DuplicateKey { key } => {
                write!(f, "metric key '{key}' is already registered")
            }
        }
    }
}

impl std::error::Error for RegistryError {}

/// The registry proper. `BTreeMap` keeps enumeration (snapshots, the
/// text dump) in stable sorted key order.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an existing instrument under `key`. This is how
    /// components absorb their loose counters: keep the arc, share it.
    pub fn register(&self, key: &str, metric: Metric) -> Result<(), RegistryError> {
        let mut map = self.inner.lock().unwrap();
        if map.contains_key(key) {
            return Err(RegistryError::DuplicateKey { key: key.to_string() });
        }
        map.insert(key.to_string(), metric);
        Ok(())
    }

    pub fn register_counter(&self, key: &str, c: Arc<Counter>) -> Result<(), RegistryError> {
        self.register(key, Metric::Counter(c))
    }

    pub fn register_gauge(&self, key: &str, g: Arc<Gauge>) -> Result<(), RegistryError> {
        self.register(key, Metric::Gauge(g))
    }

    pub fn register_histogram(
        &self,
        key: &str,
        h: Arc<LatencyHistogram>,
    ) -> Result<(), RegistryError> {
        self.register(key, Metric::Histogram(h))
    }

    /// Get-or-create a counter under `key`. Idempotent (concurrent
    /// callers converge on one instrument); panics if the key already
    /// names a different instrument kind — that is a wiring bug, not a
    /// runtime condition.
    pub fn counter(&self, key: &str) -> Arc<Counter> {
        let mut map = self.inner.lock().unwrap();
        match map
            .entry(key.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => Arc::clone(c),
            other => panic!("metric key '{key}' is not a counter: {other:?}"),
        }
    }

    /// Get-or-create a gauge under `key` (same contract as `counter`).
    pub fn gauge(&self, key: &str) -> Arc<Gauge> {
        let mut map = self.inner.lock().unwrap();
        match map
            .entry(key.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())))
        {
            Metric::Gauge(g) => Arc::clone(g),
            other => panic!("metric key '{key}' is not a gauge: {other:?}"),
        }
    }

    /// Get-or-create a latency histogram under `key`.
    pub fn histogram(&self, key: &str) -> Arc<LatencyHistogram> {
        let mut map = self.inner.lock().unwrap();
        match map
            .entry(key.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(LatencyHistogram::new())))
        {
            Metric::Histogram(h) => Arc::clone(h),
            other => panic!("metric key '{key}' is not a histogram: {other:?}"),
        }
    }

    /// Number of registered instruments.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }

    /// One coherent cut: every instrument is read under a single lock
    /// acquisition, so no concurrent registration can add or remove keys
    /// mid-snapshot. (Individual counters keep ticking — the cut is
    /// coherent over the key set and each value is a single atomic read.)
    pub fn snapshot(&self) -> MetricsSnapshot {
        let map = self.inner.lock().unwrap();
        let entries = map
            .iter()
            .map(|(k, m)| {
                let v = match m {
                    Metric::Counter(c) => MetricValue::Counter(c.get()),
                    Metric::Gauge(g) => MetricValue::Gauge(g.get()),
                    Metric::Histogram(h) => MetricValue::Histogram(h.snapshot()),
                };
                (k.clone(), v)
            })
            .collect();
        MetricsSnapshot { entries }
    }

    /// The one-page plain-text dump (per refresh cycle / at exit).
    pub fn render_text(&self) -> String {
        super::export::render_metrics(&self.snapshot())
    }
}

/// A point-in-time cut of every registered instrument, in sorted key
/// order.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub entries: Vec<(String, MetricValue)>,
}

impl MetricsSnapshot {
    pub fn get(&self, key: &str) -> Option<&MetricValue> {
        self.entries
            .binary_search_by(|(k, _)| k.as_str().cmp(key))
            .ok()
            .map(|i| &self.entries[i].1)
    }

    /// Convenience for tests and gates: the value of a counter key.
    pub fn counter(&self, key: &str) -> Option<u64> {
        match self.get(key) {
            Some(MetricValue::Counter(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn gauge(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(MetricValue::Gauge(v)) => Some(*v),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_snapshot_round_trip() {
        let reg = MetricsRegistry::new();
        reg.counter("mr.shuffle.records").add(41);
        reg.counter("mr.shuffle.records").inc(); // get-or-create converges
        reg.gauge("mr.job.2.map_ms").set(12.5);
        let hist = reg.histogram("serve.latency");
        hist.record(std::time::Duration::from_millis(3));
        let snap = reg.snapshot();
        assert_eq!(reg.len(), 3);
        assert_eq!(snap.counter("mr.shuffle.records"), Some(42));
        assert_eq!(snap.gauge("mr.job.2.map_ms"), Some(12.5));
        match snap.get("serve.latency") {
            Some(MetricValue::Histogram(h)) => assert_eq!(h.count(), 1),
            other => panic!("expected histogram, got {other:?}"),
        }
        assert!(snap.get("nope").is_none());
        assert!(snap.counter("mr.job.2.map_ms").is_none(), "wrong-kind probe");
    }

    #[test]
    fn duplicate_key_is_a_typed_error() {
        let reg = MetricsRegistry::new();
        reg.register_counter("engine.cache.hits", Arc::new(Counter::new()))
            .unwrap();
        let err = reg
            .register_counter("engine.cache.hits", Arc::new(Counter::new()))
            .unwrap_err();
        assert_eq!(
            err,
            RegistryError::DuplicateKey { key: "engine.cache.hits".into() }
        );
        assert!(err.to_string().contains("engine.cache.hits"));
        // a different kind under the same key is just as duplicate
        let err = reg
            .register_gauge("engine.cache.hits", Arc::new(Gauge::new()))
            .unwrap_err();
        assert!(matches!(err, RegistryError::DuplicateKey { .. }));
    }

    #[test]
    #[should_panic(expected = "is not a counter")]
    fn kind_mismatch_on_get_or_create_panics() {
        let reg = MetricsRegistry::new();
        reg.gauge("x");
        reg.counter("x");
    }

    #[test]
    fn snapshot_is_sorted_and_binary_searchable() {
        let reg = MetricsRegistry::new();
        for key in ["z.last", "a.first", "m.mid"] {
            reg.counter(key).inc();
        }
        let snap = reg.snapshot();
        let keys: Vec<&str> = snap.entries.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["a.first", "m.mid", "z.last"]);
        for key in keys {
            assert_eq!(snap.counter(key), Some(1));
        }
    }

    #[test]
    fn gauge_stores_last_value() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(1.5);
        g.set(-3.25);
        assert_eq!(g.get(), -3.25);
    }
}

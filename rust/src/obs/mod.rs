//! Observability: the shared nervous system of the mine→serve→persist
//! product.
//!
//! The paper's Hadoop deployment reads the framework's job counters and
//! task logs to understand where a voluminous-data mine spends its time;
//! this module is our zero-dependency equivalent, three layers deep:
//!
//! * **[`registry`]** — a process-wide [`MetricsRegistry`] of named
//!   counters, gauges, and the existing log-linear latency histograms,
//!   registered under hierarchical dotted keys (`mr.job.3.map_ms`,
//!   `serve.served`, `fabric.router.hedge_wins`) and snapshot-able as one
//!   coherent cut under a single lock acquisition.
//! * **[`trace`]** — span-based tracing with explicit parent ids:
//!   a [`TraceCtx`] is threaded through the mining driver (job → level →
//!   map-task/reduce-task spans annotated with Hadoop-style job
//!   counters), the serve path (request → shard-scatter → per-replica
//!   RPC spans, the trace id carried across the `simnet` flow model so a
//!   hedged query's winner and loser are both visible), and the durable
//!   publish commits.
//! * **[`export`]** — a JSONL event log and a Chrome `trace_event`
//!   (Perfetto-loadable) file written by `mine --trace-out` /
//!   `serve --trace-out`, plus a one-page plain-text metrics dump.
//!
//! On top of the raw telemetry sits the *analysis* layer:
//!
//! * **[`profile`]** — the critical-path extractor behind
//!   `repro analyze`: stage attribution (map / shuffle / reduce /
//!   barrier idle / driver, summing to the makespan by construction),
//!   per-wave straggler and skew detection cross-referenced against
//!   chaos `slow:` faults, and the per-level workload statistics the
//!   autotuner roadmap item calibrates on.
//! * **[`flight`]** — the flight recorder: a bounded ring of recent
//!   spans teed off the sink, dumped with a metrics cut to
//!   `--flight-dir` on job error, chaos escalation, or SLO breach.
//! * **[`slo`]** — the serve-side SLO watcher: a p99 target judged per
//!   burn-rate window over the existing latency histograms.
//!
//! Leveled logging rides along: [`log!`] replaces the ad-hoc
//! `eprintln!` call sites with structured `[level] target: message`
//! lines on **stderr** — stdout stays reserved for results and bench
//! tables (several CI smokes grep it).

pub mod export;
pub mod flight;
pub mod profile;
pub mod registry;
pub mod slo;
pub mod trace;

pub use export::{render_metrics, write_chrome_trace, write_jsonl};
pub use flight::FlightRecorder;
pub use profile::{MineProfile, ParsedSpan, ProfileError};
pub use registry::{
    Gauge, Metric, MetricValue, MetricsRegistry, MetricsSnapshot, RegistryError,
};
pub use slo::{SloConfig, SloVerdict, SloWatcher};
pub use trace::{Span, TraceCtx, TraceEvent, TraceSink};

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severities, most severe first. The global filter keeps everything
/// at or above (numerically at or below) the configured level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum LogLevel {
    Error = 0,
    Warn = 1,
    #[default]
    Info = 2,
    Debug = 3,
}

impl LogLevel {
    fn tag(self) -> &'static str {
        match self {
            Self::Error => "error",
            Self::Warn => "warn",
            Self::Info => "info",
            Self::Debug => "debug",
        }
    }
}

impl std::str::FromStr for LogLevel {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "error" => Ok(Self::Error),
            "warn" => Ok(Self::Warn),
            "info" => Ok(Self::Info),
            "debug" => Ok(Self::Debug),
            other => Err(format!(
                "unknown log level '{other}' (want error|warn|info|debug)"
            )),
        }
    }
}

impl std::fmt::Display for LogLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.tag())
    }
}

/// The `[obs]` config section (`--log-level` overrides it on the CLI).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ObsConfig {
    pub log_level: LogLevel,
}

/// Process-wide log filter; `Info` by default (`--log-level` / `[obs]`
/// override it at startup).
static LOG_LEVEL: AtomicU8 = AtomicU8::new(LogLevel::Info as u8);

pub fn set_log_level(level: LogLevel) {
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log_level() -> LogLevel {
    match LOG_LEVEL.load(Ordering::Relaxed) {
        0 => LogLevel::Error,
        1 => LogLevel::Warn,
        2 => LogLevel::Info,
        _ => LogLevel::Debug,
    }
}

/// Would an event at `level` pass the global filter? The [`log!`] macro
/// checks this before formatting, so suppressed events cost one relaxed
/// atomic load.
pub fn enabled(level: LogLevel) -> bool {
    level <= log_level()
}

/// Write one formatted event to stderr. Called by [`log!`] after the
/// level check; the line shape is `[level] target: message`.
pub fn emit(level: LogLevel, target: &str, args: std::fmt::Arguments<'_>) {
    eprintln!("[{}] {}: {}", level.tag(), target, args);
}

/// Leveled structured logging: `obs::log!(Warn, "slow cycle: {secs}s")`.
///
/// Events go to stderr (stdout belongs to results); the target is the
/// call site's module path. Formatting is skipped entirely when the
/// level is filtered out.
#[macro_export]
macro_rules! log {
    ($level:ident, $($arg:tt)*) => {
        if $crate::obs::enabled($crate::obs::LogLevel::$level) {
            $crate::obs::emit(
                $crate::obs::LogLevel::$level,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

pub use crate::log;

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn levels_order_and_parse() {
        assert!(LogLevel::Error < LogLevel::Warn);
        assert!(LogLevel::Warn < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Debug);
        for s in ["error", "warn", "info", "debug"] {
            assert_eq!(LogLevel::from_str(s).unwrap().to_string(), s);
        }
        assert!(LogLevel::from_str("verbose").is_err());
        assert_eq!(LogLevel::default(), LogLevel::Info);
    }

    #[test]
    fn filter_respects_global_level() {
        // Tests run concurrently in one process; restore the default so
        // other tests' log expectations are unaffected.
        set_log_level(LogLevel::Warn);
        assert!(enabled(LogLevel::Error));
        assert!(enabled(LogLevel::Warn));
        assert!(!enabled(LogLevel::Info));
        assert!(!enabled(LogLevel::Debug));
        set_log_level(LogLevel::Info);
        assert!(enabled(LogLevel::Info));
        log!(Debug, "filtered out, never formatted");
    }
}

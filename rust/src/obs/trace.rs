//! Span-based tracing with explicit parent ids.
//!
//! A [`TraceSink`] buffers completed spans as flat [`TraceEvent`]s; a
//! [`TraceCtx`] is the cheap, cloneable handle a caller threads through
//! the code it wants traced (the mining driver, the serve path, the
//! publish commits). Opening a [`Span`] from a context stamps the start
//! time; dropping it records the event, so the tree shape falls out of
//! ordinary scoping. Disabled tracing is represented as
//! `Option<TraceCtx> = None` at every integration point — the off path
//! costs one branch, which is what keeps the measured overhead of the
//! instrumentation under the 5% budget `benches/ablation_obs.rs` gates.
//!
//! Two clocks coexist (DESIGN.md §Observability): spans on the real
//! execution path (`cat` `mine`/`mr`/`serve`/`store`) measure wall-clock
//! time, while spans inside the *simulated* cluster (`cat` `rpc`/`net`)
//! carry a wall-clock start but a **simulated** duration injected via
//! [`Span::set_dur_us`] — the flow-model transfer time the `simnet`
//! module computed. Exporters keep both; nesting checks only trust the
//! wall-clock categories.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::flight::FlightRecorder;

/// One completed span, flattened for export.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    /// Coarse category: `mine`, `mr`, `serve`, `rpc`, `net`, `store`.
    pub cat: &'static str,
    /// Groups every span of one logical operation (a mine run, one
    /// served request) — propagated unchanged to every child.
    pub trace_id: u64,
    /// Unique per sink; `parent_id == 0` marks a root span.
    pub span_id: u64,
    pub parent_id: u64,
    /// Microseconds since the sink's epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Recording thread (stable hash of the OS thread id) — Perfetto
    /// lays concurrent map tasks out on separate rows by this.
    pub tid: u64,
    /// Hadoop-style job counters and other numeric annotations.
    pub args: Vec<(String, f64)>,
}

/// The shared buffer completed spans land in. One sink per traced
/// command; cheap enough to leave attached for a whole serve run.
#[derive(Debug)]
pub struct TraceSink {
    epoch: Instant,
    next_id: AtomicU64,
    events: Mutex<Vec<TraceEvent>>,
    /// Optional flight-recorder tee (attach-once; the off path costs one
    /// atomic load per record, keeping the instrumentation budget).
    flight: OnceLock<Arc<FlightRecorder>>,
}

impl TraceSink {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            events: Mutex::new(Vec::new()),
            flight: OnceLock::new(),
        })
    }

    /// Tee every span recorded from now on into `recorder` (its bounded
    /// ring). At most one recorder per sink; later attaches are no-ops.
    pub fn attach_flight(&self, recorder: Arc<FlightRecorder>) {
        let _ = self.flight.set(recorder);
    }

    /// The attached flight recorder, if any — trigger sites (job error,
    /// chaos escalation, SLO breach) reach it through the sink.
    pub fn flight(&self) -> Option<&Arc<FlightRecorder>> {
        self.flight.get()
    }

    /// Microseconds since the sink was created.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn record(&self, event: TraceEvent) {
        if let Some(flight) = self.flight.get() {
            flight.observe(&event);
        }
        self.events.lock().unwrap().push(event);
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A copy of everything recorded so far (export-time call; spans
    /// still open are not included).
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }
}

/// A position in the span tree: "children opened through me get this
/// span as their parent". Clone + Send so it crosses the scoped-thread
/// boundaries of the map/reduce phases and the serve workers.
#[derive(Debug, Clone)]
pub struct TraceCtx {
    sink: Arc<TraceSink>,
    pub trace_id: u64,
    /// The surrounding span (0 at the root).
    pub span_id: u64,
}

impl TraceCtx {
    /// A fresh root context: the next span opened from it starts a new
    /// tree, and `trace_id` tags the whole tree.
    pub fn root(sink: Arc<TraceSink>) -> Self {
        let trace_id = sink.next_id();
        Self { sink, trace_id, span_id: 0 }
    }

    pub fn sink(&self) -> &Arc<TraceSink> {
        &self.sink
    }

    /// Open a child span. Recorded when dropped.
    pub fn span(&self, cat: &'static str, name: impl Into<String>) -> Span {
        Span {
            sink: Arc::clone(&self.sink),
            trace_id: self.trace_id,
            span_id: self.sink.next_id(),
            parent_id: self.span_id,
            cat,
            name: name.into(),
            start_us: self.sink.now_us(),
            dur_us: None,
            args: Vec::new(),
        }
    }
}

/// An open span; records itself into the sink on drop.
#[derive(Debug)]
pub struct Span {
    sink: Arc<TraceSink>,
    trace_id: u64,
    span_id: u64,
    parent_id: u64,
    cat: &'static str,
    name: String,
    start_us: u64,
    /// Simulated-duration override (see module docs); `None` means
    /// wall-clock measured at drop.
    dur_us: Option<u64>,
    args: Vec<(String, f64)>,
}

impl Span {
    /// Attach a numeric annotation (a Hadoop-style job counter, a byte
    /// count, a flag encoded 0/1).
    pub fn add(&mut self, key: &str, value: f64) {
        self.args.push((key.to_string(), value));
    }

    /// Override the duration with simulated time (µs) — used by the
    /// `rpc`/`net` spans whose cost comes from the flow model, not the
    /// wall clock.
    pub fn set_dur_us(&mut self, dur_us: u64) {
        self.dur_us = Some(dur_us);
    }

    /// A context for children of this span.
    pub fn ctx(&self) -> TraceCtx {
        TraceCtx {
            sink: Arc::clone(&self.sink),
            trace_id: self.trace_id,
            span_id: self.span_id,
        }
    }

    pub fn span_id(&self) -> u64 {
        self.span_id
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let dur_us = self
            .dur_us
            .unwrap_or_else(|| self.sink.now_us().saturating_sub(self.start_us));
        self.sink.record(TraceEvent {
            name: std::mem::take(&mut self.name),
            cat: self.cat,
            trace_id: self.trace_id,
            span_id: self.span_id,
            parent_id: self.parent_id,
            start_us: self.start_us,
            dur_us,
            tid: current_tid(),
            args: std::mem::take(&mut self.args),
        });
    }
}

/// A stable small-ish integer for the current OS thread: `ThreadId` has
/// no stable numeric accessor, so hash it.
fn current_tid() -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    std::thread::current().id().hash(&mut h);
    h.finish() % 1_000_000
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_record_on_drop_with_parent_links() {
        let sink = TraceSink::new();
        let root = TraceCtx::root(Arc::clone(&sink));
        {
            let mut job = root.span("mine", "job");
            job.add("n_tx", 9.0);
            {
                let mut level = job.ctx().span("mine", "level.2");
                level.add("candidates", 10.0);
            }
        }
        let events = sink.events();
        assert_eq!(events.len(), 2);
        // children drop (and record) before their parents
        let (level, job) = (&events[0], &events[1]);
        assert_eq!(level.name, "level.2");
        assert_eq!(job.name, "job");
        assert_eq!(job.parent_id, 0);
        assert_eq!(level.parent_id, job.span_id);
        assert_eq!(level.trace_id, job.trace_id);
        assert_ne!(level.span_id, job.span_id);
        assert_eq!(job.args, vec![("n_tx".to_string(), 9.0)]);
        // wall-clock containment: the parent closed after the child
        assert!(job.start_us <= level.start_us);
        assert!(job.start_us + job.dur_us >= level.start_us + level.dur_us);
    }

    #[test]
    fn simulated_duration_overrides_wall_clock() {
        let sink = TraceSink::new();
        let ctx = TraceCtx::root(Arc::clone(&sink));
        {
            let mut rpc = ctx.span("rpc", "shard.0");
            rpc.set_dur_us(5_000_000); // 5 simulated seconds, ~0 wall
            rpc.add("winner", 1.0);
        }
        let ev = &sink.events()[0];
        assert_eq!(ev.dur_us, 5_000_000);
        assert_eq!(ev.cat, "rpc");
    }

    #[test]
    fn contexts_cross_threads() {
        let sink = TraceSink::new();
        let root = TraceCtx::root(Arc::clone(&sink));
        let parent = root.span("mr", "map_phase");
        std::thread::scope(|scope| {
            for task in 0..4 {
                let ctx = parent.ctx();
                scope.spawn(move || {
                    let mut span = ctx.span("mr", format!("map.task.{task}"));
                    span.add("records_read", 100.0);
                });
            }
        });
        let parent_id = parent.span_id();
        drop(parent);
        let events = sink.events();
        assert_eq!(events.len(), 5);
        assert_eq!(
            events.iter().filter(|e| e.parent_id == parent_id).count(),
            4
        );
        // ids are unique even under concurrent allocation
        let mut ids: Vec<u64> = events.iter().map(|e| e.span_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 5);
    }

    #[test]
    fn distinct_roots_get_distinct_trace_ids() {
        let sink = TraceSink::new();
        let a = TraceCtx::root(Arc::clone(&sink));
        let b = TraceCtx::root(Arc::clone(&sink));
        assert_ne!(a.trace_id, b.trace_id);
    }
}

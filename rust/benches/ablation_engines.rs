//! Ablation A1: candidate-matcher engines on the level-2 counting hot
//! path — hash tree vs trie vs naive scan vs the Pallas/PJRT tensor
//! engine (when artifacts are built). Reports per-call counting time on
//! one map-split worth of transactions across candidate-set widths.

use std::time::Instant;

use mr_apriori::apriori::candidates;
use mr_apriori::prelude::*;
use mr_apriori::runtime::TensorService;

fn main() {
    println!("== Ablation A1: support-count engines ==\n");
    // A 64-item dictionary so the tensor small-variant fits directly.
    let db = QuestGenerator::new(QuestParams {
        n_items: 64,
        ..QuestParams::dense(1_000)
    })
    .generate();
    let split = &db.transactions[..512];

    // Level-2 candidates from the actual frequent items.
    let cfg = AprioriConfig { min_support: 0.05, max_k: 1 };
    let f1 = ClassicalApriori::default().mine(&db, &cfg);
    let f1_sets: Vec<Itemset> = f1.frequent.iter().map(|(is, _)| is.clone()).collect();
    let all_c2 = candidates::generate(&f1_sets);
    println!(
        "{} frequent items -> {} level-2 candidates; split = {} tx\n",
        f1_sets.len(),
        all_c2.len(),
        split.len()
    );

    let tensor_service = TensorService::start_default().ok();
    let mut engines: Vec<(&str, Box<dyn SupportEngine>)> = vec![
        ("hash-tree", build_engine(EngineKind::HashTree, None)),
        ("trie", build_engine(EngineKind::Trie, None)),
        ("naive", build_engine(EngineKind::Naive, None)),
    ];
    if let Some(svc) = &tensor_service {
        engines.push(("tensor", build_engine(EngineKind::Tensor, Some(svc.handle()))));
    } else {
        println!("(artifacts not built — tensor engine skipped; run `make artifacts`)\n");
    }

    let widths: Vec<usize> = [64usize, 128, 256, 512]
        .iter()
        .copied()
        .filter(|&w| w <= all_c2.len())
        .collect();
    let mut table = BenchTable::new(
        "A1 — counting time (ms) vs candidate count, one 512-tx split",
        "candidates",
        widths.iter().map(|&w| w as f64).collect(),
    );

    let reference: Vec<Vec<u64>> = widths
        .iter()
        .map(|&w| {
            build_engine(EngineKind::Naive, None)
                .count(split, &all_c2[..w], db.n_items)
                .unwrap()
        })
        .collect();

    for (name, engine) in &engines {
        let mut times = Vec::new();
        for (wi, &w) in widths.iter().enumerate() {
            let cands = &all_c2[..w];
            // warmup + correctness check against the naive oracle
            let counts = engine.count(split, cands, db.n_items).unwrap();
            assert_eq!(counts, reference[wi], "{name} wrong at width {w}");
            let iters = 5;
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(engine.count(split, cands, db.n_items).unwrap());
            }
            times.push(t0.elapsed().as_secs_f64() * 1e3 / iters as f64);
        }
        table.push_series(Series::new(*name, times));
    }
    table.emit();
    println!("all engines agree with the naive oracle at every width");
}

//! Ablation A1: candidate-matcher engines on the level-2 counting hot
//! path — hash tree vs trie vs the vertical TID-bitset engine vs naive
//! scan vs the Pallas/PJRT tensor engine (when artifacts are built).
//! Reports per-call counting time on one map-split worth of transactions
//! across candidate-set widths, plus a batched two-level shared-scan row.
//!
//! Run with `--quick` for the CI bench smoke: a smaller deterministic
//! workload whose results land in `BENCH_engines.json` (override the
//! directory with `BENCH_OUT_DIR`) — one row per engine with wall-clock,
//! scan counts and peak index bytes, so the perf trajectory is tracked
//! per push. Inline assertions prove every engine agrees with the naive
//! oracle at every width, that `engine = vertical` produces byte-identical
//! `MiningResult`s to hash-tree on the classical, pipelined and
//! incremental mining paths, and that vertical beats hash-tree on this
//! dense synthetic workload.

use std::time::Instant;

use mr_apriori::apriori::candidates;
use mr_apriori::prelude::*;
use mr_apriori::runtime::TensorService;
use mr_apriori::util::json::Json;

/// One engine's measured row for `BENCH_engines.json`.
struct EngineRow {
    name: &'static str,
    /// Per-width best-of-iters count() wall-clock, ms (aligned with
    /// `widths`; minimum is robust to CI runner noise).
    wall_ms: Vec<f64>,
    /// Batched two-level shared-scan wall-clock, ms.
    batch_ms: f64,
    /// Logical passes over the split during the timed sections.
    scans: usize,
    /// Peak counting-structure footprint for the widest candidate set
    /// (measured for vertical/tensor; itemset-payload estimate for the
    /// pointer-based matchers).
    peak_index_bytes: usize,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_tx, split_len, iters) = if quick { (600, 384, 3) } else { (1_000, 512, 5) };
    println!(
        "== Ablation A1: support-count engines{} ==\n",
        if quick { " (quick mode)" } else { "" }
    );

    // A 64-item dictionary so the tensor small-variant fits directly —
    // and the dense synthetic workload the vertical engine's bitset rows
    // are built for.
    let db = QuestGenerator::new(QuestParams {
        n_items: 64,
        ..QuestParams::dense(n_tx)
    })
    .generate();
    let split = &db.transactions[..split_len];

    // Level-2 candidates from the actual frequent items.
    let cfg = AprioriConfig { min_support: 0.05, max_k: 1 };
    let f1 = ClassicalApriori::default().mine(&db, &cfg);
    let f1_sets: Vec<Itemset> = f1.frequent.iter().map(|(is, _)| is.clone()).collect();
    let all_c2 = candidates::generate(&f1_sets);
    println!(
        "{} frequent items -> {} level-2 candidates; split = {} tx\n",
        f1_sets.len(),
        all_c2.len(),
        split.len()
    );

    let tensor_service = TensorService::start_default().ok();
    let mut engines: Vec<(&'static str, Box<dyn SupportEngine>)> = vec![
        ("hash-tree", build_engine(EngineKind::HashTree, None)),
        ("trie", build_engine(EngineKind::Trie, None)),
        ("vertical", build_engine(EngineKind::Vertical, None)),
        ("naive", build_engine(EngineKind::Naive, None)),
    ];
    if let Some(svc) = &tensor_service {
        engines.push(("tensor", build_engine(EngineKind::Tensor, Some(svc.handle()))));
    } else {
        println!("(artifacts not built — tensor engine skipped; run `make artifacts`)\n");
    }

    let widths: Vec<usize> = [64usize, 128, 256, 512]
        .iter()
        .copied()
        .filter(|&w| w <= all_c2.len())
        .collect();
    let mut table = BenchTable::new(
        format!(
            "A1 — counting time (ms) vs candidate count, one {}-tx split",
            split.len()
        ),
        "candidates",
        widths.iter().map(|&w| w as f64).collect(),
    );

    let reference: Vec<Vec<u64>> = widths
        .iter()
        .map(|&w| {
            build_engine(EngineKind::Naive, None)
                .count(split, &all_c2[..w], db.n_items)
                .unwrap()
        })
        .collect();

    // Batched two-level groups for the shared-scan row: the widest c2
    // slice plus the level-3 candidates it generates.
    let batch_c2 = all_c2[..widths.last().copied().unwrap_or(all_c2.len())].to_vec();
    let batch_c3 = candidates::generate(&batch_c2);
    let groups = vec![batch_c2.clone(), batch_c3.clone()];
    let batch_reference: Vec<Vec<u64>> = groups
        .iter()
        .map(|g| {
            build_engine(EngineKind::Naive, None)
                .count(split, g, db.n_items)
                .unwrap()
        })
        .collect();

    let mut rows: Vec<EngineRow> = Vec::new();
    for (name, engine) in &engines {
        let mut times = Vec::new();
        let mut scans = 0usize;
        for (wi, &w) in widths.iter().enumerate() {
            let cands = &all_c2[..w];
            // warmup + correctness check against the naive oracle
            let counts = engine.count(split, cands, db.n_items).unwrap();
            assert_eq!(counts, reference[wi], "{name} wrong at width {w}");
            // Best-of-N: the minimum is robust to scheduler noise on
            // shared CI runners, where this binary gates the push.
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let t0 = Instant::now();
                std::hint::black_box(engine.count(split, cands, db.n_items).unwrap());
                best = best.min(t0.elapsed().as_secs_f64() * 1e3);
            }
            scans += iters; // one pass over the split per count() call
            times.push(best);
        }

        // Shared scan: both levels in one pass over the split.
        let t0 = Instant::now();
        let batched = engine.count_batch(split, &groups, db.n_items).unwrap();
        let batch_ms = t0.elapsed().as_secs_f64() * 1e3;
        scans += 1;
        assert_eq!(batched, batch_reference, "{name} wrong on the batched scan");

        let widest = widths.last().copied().unwrap_or(0);
        let peak_index_bytes = match *name {
            "vertical" => {
                VerticalIndex::build(&FlatBlock::from_transactions(split, db.n_items)).bytes()
            }
            "tensor" => BitmapBlock::encode(split, db.n_items, 256)
                .map(|b| b.bytes())
                .unwrap_or(0),
            // Pointer-based matchers: itemset payload + per-candidate
            // node overhead estimate (they expose no exact footprint).
            "hash-tree" | "trie" => all_c2[..widest]
                .iter()
                .map(|c| c.len() * 4 + 16)
                .sum(),
            _ => 0,
        };
        rows.push(EngineRow {
            name: *name,
            wall_ms: times.clone(),
            batch_ms,
            scans,
            peak_index_bytes,
        });
        table.push_series(Series::new(*name, times));
    }
    table.emit();
    println!("all engines agree with the naive oracle at every width (batched scan included)");

    // -- the headline comparison: vertical must beat hash-tree on this
    //    dense workload, per width-summed wall-clock --
    let total = |n: &str| -> f64 {
        rows.iter()
            .find(|r| r.name == n)
            .map(|r| r.wall_ms.iter().sum())
            .expect("row present")
    };
    let (ht, vert) = (total("hash-tree"), total("vertical"));
    println!(
        "\nvertical {:.3} ms vs hash-tree {:.3} ms across widths ({:.1}x)",
        vert,
        ht,
        ht / vert.max(1e-9)
    );
    assert!(
        vert < ht,
        "vertical ({vert:.3} ms) must beat hash-tree ({ht:.3} ms) on the dense workload"
    );

    // -- inline path equivalence: classical, pipelined, incremental --
    let mine_cfg = AprioriConfig { min_support: 0.05, max_k: 4 };
    let driver = |kind: EngineKind| {
        MrApriori::new(ClusterConfig::fhssc(2), mine_cfg.clone())
            .with_engine(build_engine(kind, None))
            .with_split_tx(150)
    };
    let base = driver(EngineKind::HashTree).mine(&db).unwrap();
    let sync = driver(EngineKind::Vertical).mine(&db).unwrap();
    assert_eq!(
        base.result.frequent, sync.result.frequent,
        "vertical diverged on the classical path"
    );
    let piped = driver(EngineKind::Vertical)
        .with_pipeline(PipelineConfig::pipelined())
        .mine(&db)
        .unwrap();
    assert_eq!(
        base.result.frequent, piped.result.frequent,
        "vertical diverged on the pipelined path"
    );
    let mut inc_db = TransactionDb::new(db.transactions[..n_tx / 2].to_vec());
    let vertical_driver = driver(EngineKind::Vertical);
    let (_, mut state) = MinedState::capture(&vertical_driver, &inc_db).unwrap();
    let delta = synth_delta(60, inc_db.n_items, 0xA1);
    inc_db.append(delta.clone());
    if let DeltaApply::FrontierBlowup { .. } = state
        .apply_delta(&vertical_driver, &inc_db, &delta, &IncrementalConfig::default())
        .unwrap()
    {
        let (_, fresh) = MinedState::capture(&vertical_driver, &inc_db).unwrap();
        state = fresh;
    }
    let inc_base = driver(EngineKind::HashTree).mine(&inc_db).unwrap();
    assert_eq!(
        state.to_result().frequent,
        inc_base.result.frequent,
        "vertical diverged on the incremental path"
    );
    println!(
        "engine = vertical byte-identical to hash-tree on classical, pipelined and \
         incremental paths"
    );

    // -- BENCH_engines.json: the tracked perf trajectory --
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("engine", Json::str(r.name)),
                (
                    "wall_ms",
                    Json::Arr(r.wall_ms.iter().map(|&t| Json::num(t)).collect()),
                ),
                ("total_wall_ms", Json::num(r.wall_ms.iter().sum())),
                ("batch_ms", Json::num(r.batch_ms)),
                ("scans", Json::num(r.scans as f64)),
                ("peak_index_bytes", Json::num(r.peak_index_bytes as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("ablation_engines")),
        ("mode", Json::str(if quick { "quick" } else { "full" })),
        ("split_tx", Json::num(split.len() as f64)),
        ("n_items", Json::num(db.n_items as f64)),
        (
            "widths",
            Json::Arr(widths.iter().map(|&w| Json::num(w as f64)).collect()),
        ),
        (
            "batch_levels",
            Json::Arr(vec![
                Json::num(batch_c2.len() as f64),
                Json::num(batch_c3.len() as f64),
            ]),
        ),
        ("vertical_speedup_vs_hash_tree", Json::num(ht / vert.max(1e-9))),
        ("rows", Json::Arr(json_rows)),
    ]);
    let dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&dir).join("BENCH_engines.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_engines.json");
    println!("wrote {}", path.display());
}

//! Ablation A1: candidate-matcher engines on the level-2 counting hot
//! path — hash tree vs trie vs the vertical TID-bitset engine vs naive
//! scan vs the Pallas/PJRT tensor engine (when artifacts are built).
//! Reports per-call counting time on one map-split worth of transactions
//! across candidate-set widths, plus a batched two-level shared-scan row.
//!
//! Run with `--quick` for the CI bench smoke: a smaller deterministic
//! workload whose results land in `BENCH_engines.json` (override the
//! directory with `BENCH_OUT_DIR`) — one row per engine with wall-clock,
//! scan counts and peak index bytes, so the perf trajectory is tracked
//! per push. Inline assertions prove every engine agrees with the naive
//! oracle at every width, that `engine = vertical` produces byte-identical
//! `MiningResult`s to hash-tree on the classical, pipelined and
//! incremental mining paths, and that vertical beats hash-tree on this
//! dense synthetic workload.
//!
//! The second half is the container occupancy sweep: three QUEST
//! profiles spanning dense → sparse, each intersected both through the
//! chunked [`Container`] layouts and through a local whole-row
//! dense-bitset comparator (the dense half of the pre-container
//! dichotomy). Per profile the JSON gets a win/loss row (time, bytes,
//! container census); inline assertions force every forced-variant
//! kernel pairing byte-identical to the sorted-merge oracle and require
//! the compressed containers to beat dense rows on the sparse profile.

use std::time::Instant;

use mr_apriori::apriori::candidates;
use mr_apriori::engine::{Container, ContainerCensus, TidSet};
use mr_apriori::prelude::*;
use mr_apriori::runtime::TensorService;
use mr_apriori::util::json::Json;

/// One engine's measured row for `BENCH_engines.json`.
struct EngineRow {
    name: &'static str,
    /// Per-width best-of-iters count() wall-clock, ms (aligned with
    /// `widths`; minimum is robust to CI runner noise).
    wall_ms: Vec<f64>,
    /// Batched two-level shared-scan wall-clock, ms.
    batch_ms: f64,
    /// Logical passes over the split during the timed sections.
    scans: usize,
    /// Peak counting-structure footprint for the widest candidate set
    /// (measured for vertical/tensor; itemset-payload estimate for the
    /// pointer-based matchers).
    peak_index_bytes: usize,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_tx, split_len, iters) = if quick { (600, 384, 3) } else { (1_000, 512, 5) };
    println!(
        "== Ablation A1: support-count engines{} ==\n",
        if quick { " (quick mode)" } else { "" }
    );

    // A 64-item dictionary so the tensor small-variant fits directly —
    // and the dense synthetic workload the vertical engine's bitset rows
    // are built for.
    let db = QuestGenerator::new(QuestParams {
        n_items: 64,
        ..QuestParams::dense(n_tx)
    })
    .generate();
    let split = &db.transactions[..split_len];

    // Level-2 candidates from the actual frequent items.
    let cfg = AprioriConfig { min_support: 0.05, max_k: 1 };
    let f1 = ClassicalApriori::default().mine(&db, &cfg);
    let f1_sets: Vec<Itemset> = f1.frequent.iter().map(|(is, _)| is.clone()).collect();
    let all_c2 = candidates::generate(&f1_sets);
    println!(
        "{} frequent items -> {} level-2 candidates; split = {} tx\n",
        f1_sets.len(),
        all_c2.len(),
        split.len()
    );

    let tensor_service = TensorService::start_default().ok();
    let mut engines: Vec<(&'static str, Box<dyn SupportEngine>)> = vec![
        ("hash-tree", build_engine(EngineKind::HashTree, None)),
        ("trie", build_engine(EngineKind::Trie, None)),
        ("vertical", build_engine(EngineKind::Vertical, None)),
        ("naive", build_engine(EngineKind::Naive, None)),
    ];
    if let Some(svc) = &tensor_service {
        engines.push(("tensor", build_engine(EngineKind::Tensor, Some(svc.handle()))));
    } else {
        println!("(artifacts not built — tensor engine skipped; run `make artifacts`)\n");
    }

    let widths: Vec<usize> = [64usize, 128, 256, 512]
        .iter()
        .copied()
        .filter(|&w| w <= all_c2.len())
        .collect();
    let mut table = BenchTable::new(
        format!(
            "A1 — counting time (ms) vs candidate count, one {}-tx split",
            split.len()
        ),
        "candidates",
        widths.iter().map(|&w| w as f64).collect(),
    );

    let reference: Vec<Vec<u64>> = widths
        .iter()
        .map(|&w| {
            build_engine(EngineKind::Naive, None)
                .count(split, &all_c2[..w], db.n_items)
                .unwrap()
        })
        .collect();

    // Batched two-level groups for the shared-scan row: the widest c2
    // slice plus the level-3 candidates it generates.
    let batch_c2 = all_c2[..widths.last().copied().unwrap_or(all_c2.len())].to_vec();
    let batch_c3 = candidates::generate(&batch_c2);
    let groups = vec![batch_c2.clone(), batch_c3.clone()];
    let batch_reference: Vec<Vec<u64>> = groups
        .iter()
        .map(|g| {
            build_engine(EngineKind::Naive, None)
                .count(split, g, db.n_items)
                .unwrap()
        })
        .collect();

    let mut rows: Vec<EngineRow> = Vec::new();
    for (name, engine) in &engines {
        let mut times = Vec::new();
        let mut scans = 0usize;
        for (wi, &w) in widths.iter().enumerate() {
            let cands = &all_c2[..w];
            // warmup + correctness check against the naive oracle
            let counts = engine.count(split, cands, db.n_items).unwrap();
            assert_eq!(counts, reference[wi], "{name} wrong at width {w}");
            // Best-of-N: the minimum is robust to scheduler noise on
            // shared CI runners, where this binary gates the push.
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let t0 = Instant::now();
                std::hint::black_box(engine.count(split, cands, db.n_items).unwrap());
                best = best.min(t0.elapsed().as_secs_f64() * 1e3);
            }
            scans += iters; // one pass over the split per count() call
            times.push(best);
        }

        // Shared scan: both levels in one pass over the split.
        let t0 = Instant::now();
        let batched = engine.count_batch(split, &groups, db.n_items).unwrap();
        let batch_ms = t0.elapsed().as_secs_f64() * 1e3;
        scans += 1;
        assert_eq!(batched, batch_reference, "{name} wrong on the batched scan");

        let widest = widths.last().copied().unwrap_or(0);
        let peak_index_bytes = match *name {
            "vertical" => {
                VerticalIndex::build(&FlatBlock::from_transactions(split, db.n_items)).bytes()
            }
            "tensor" => BitmapBlock::encode(split, db.n_items, 256)
                .map(|b| b.bytes())
                .unwrap_or(0),
            // Pointer-based matchers: itemset payload + per-candidate
            // node overhead estimate (they expose no exact footprint).
            "hash-tree" | "trie" => all_c2[..widest]
                .iter()
                .map(|c| c.len() * 4 + 16)
                .sum(),
            _ => 0,
        };
        rows.push(EngineRow {
            name: *name,
            wall_ms: times.clone(),
            batch_ms,
            scans,
            peak_index_bytes,
        });
        table.push_series(Series::new(*name, times));
    }
    table.emit();
    println!("all engines agree with the naive oracle at every width (batched scan included)");

    // -- the headline comparison: vertical must beat hash-tree on this
    //    dense workload, per width-summed wall-clock --
    let total = |n: &str| -> f64 {
        rows.iter()
            .find(|r| r.name == n)
            .map(|r| r.wall_ms.iter().sum())
            .expect("row present")
    };
    let (ht, vert) = (total("hash-tree"), total("vertical"));
    println!(
        "\nvertical {:.3} ms vs hash-tree {:.3} ms across widths ({:.1}x)",
        vert,
        ht,
        ht / vert.max(1e-9)
    );
    assert!(
        vert < ht,
        "vertical ({vert:.3} ms) must beat hash-tree ({ht:.3} ms) on the dense workload"
    );

    // -- inline path equivalence: classical, pipelined, incremental --
    let mine_cfg = AprioriConfig { min_support: 0.05, max_k: 4 };
    let driver = |kind: EngineKind| {
        MrApriori::new(ClusterConfig::fhssc(2), mine_cfg.clone())
            .with_engine(build_engine(kind, None))
            .with_split_tx(150)
    };
    let base = driver(EngineKind::HashTree).mine(&db).unwrap();
    let sync = driver(EngineKind::Vertical).mine(&db).unwrap();
    assert_eq!(
        base.result.frequent, sync.result.frequent,
        "vertical diverged on the classical path"
    );
    let piped = driver(EngineKind::Vertical)
        .with_pipeline(PipelineConfig::pipelined())
        .mine(&db)
        .unwrap();
    assert_eq!(
        base.result.frequent, piped.result.frequent,
        "vertical diverged on the pipelined path"
    );
    let mut inc_db = TransactionDb::new(db.transactions[..n_tx / 2].to_vec());
    let vertical_driver = driver(EngineKind::Vertical);
    let (_, mut state) = MinedState::capture(&vertical_driver, &inc_db).unwrap();
    let delta = synth_delta(60, inc_db.n_items, 0xA1);
    inc_db.append(delta.clone());
    if let DeltaApply::FrontierBlowup { .. } = state
        .apply_delta(&vertical_driver, &inc_db, &delta, &IncrementalConfig::default())
        .unwrap()
    {
        let (_, fresh) = MinedState::capture(&vertical_driver, &inc_db).unwrap();
        state = fresh;
    }
    let inc_base = driver(EngineKind::HashTree).mine(&inc_db).unwrap();
    assert_eq!(
        state.to_result().frequent,
        inc_base.result.frequent,
        "vertical diverged on the incremental path"
    );
    println!(
        "engine = vertical byte-identical to hash-tree on classical, pipelined and \
         incremental paths"
    );

    // -- container occupancy sweep: dense -> sparse profiles --
    let occupancy = occupancy_sweep(quick);

    // -- BENCH_engines.json: the tracked perf trajectory --
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("engine", Json::str(r.name)),
                (
                    "wall_ms",
                    Json::Arr(r.wall_ms.iter().map(|&t| Json::num(t)).collect()),
                ),
                ("total_wall_ms", Json::num(r.wall_ms.iter().sum())),
                ("batch_ms", Json::num(r.batch_ms)),
                ("scans", Json::num(r.scans as f64)),
                ("peak_index_bytes", Json::num(r.peak_index_bytes as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("ablation_engines")),
        ("mode", Json::str(if quick { "quick" } else { "full" })),
        ("split_tx", Json::num(split.len() as f64)),
        ("n_items", Json::num(db.n_items as f64)),
        (
            "widths",
            Json::Arr(widths.iter().map(|&w| Json::num(w as f64)).collect()),
        ),
        (
            "batch_levels",
            Json::Arr(vec![
                Json::num(batch_c2.len() as f64),
                Json::num(batch_c3.len() as f64),
            ]),
        ),
        ("vertical_speedup_vs_hash_tree", Json::num(ht / vert.max(1e-9))),
        ("rows", Json::Arr(json_rows)),
        ("occupancy", occupancy),
    ]);
    let dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&dir).join("BENCH_engines.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_engines.json");
    println!("wrote {}", path.display());
}

/// The pre-container comparator: one whole-row dense bitset per item —
/// the dense half of the old row-level dense/sparse dichotomy the
/// chunked containers replaced.
struct DenseRows {
    rows: Vec<Vec<u64>>,
}

impl DenseRows {
    fn build(lists: &[&[u32]], n_tx: usize) -> Self {
        let words = n_tx.div_ceil(64);
        let rows = lists
            .iter()
            .map(|tids| {
                let mut row = vec![0u64; words];
                for &t in *tids {
                    row[t as usize / 64] |= 1u64 << (t % 64);
                }
                row
            })
            .collect();
        Self { rows }
    }

    fn pair_count(&self, a: usize, b: usize) -> u64 {
        self.rows[a]
            .iter()
            .zip(&self.rows[b])
            .map(|(x, y)| (x & y).count_ones() as u64)
            .sum()
    }
}

/// Sorted-merge intersection — the oracle every container kernel must
/// reproduce byte-for-byte.
fn merge_intersect(a: &[u16], b: &[u16]) -> Vec<u16> {
    let (mut i, mut j) = (0usize, 0usize);
    let mut out = Vec::new();
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out
}

/// Every forced-variant kernel pairing (array/bitmap/runs on each side)
/// over the given sorted single-chunk TID lists, checked against the
/// merge oracle for both the count and the materialized intersection.
fn check_kernel_pairings(a: &[u16], b: &[u16], span: usize) {
    let oracle = merge_intersect(a, b);
    let forced = |tids: &[u16]| {
        [
            Container::array(tids.to_vec()),
            Container::bitmap_from_sorted(tids, span),
            Container::runs_from_sorted(tids),
        ]
    };
    for ca in &forced(a) {
        for cb in &forced(b) {
            assert_eq!(
                ca.intersect_count(cb),
                oracle.len() as u64,
                "kernel count diverges from the merge oracle"
            );
            assert_eq!(
                ca.intersect(cb, span).decode(),
                oracle,
                "materialized kernel diverges from the merge oracle"
            );
        }
    }
}

/// Dense → sparse QUEST profiles, each pair-counted both through the
/// chunked containers and through [`DenseRows`]; returns the
/// `"occupancy"` object for `BENCH_engines.json`. Asserts inline that
/// both representations match the naive oracle, that all nine
/// forced-variant kernel pairings match the merge oracle, and that the
/// compressed containers win (time *and* bytes) on the sparse profile.
fn occupancy_sweep(quick: bool) -> Json {
    let (occ_tx, iters, reps) = if quick { (8192, 3, 8) } else { (16384, 5, 16) };
    let profiles: [(&str, QuestParams); 3] = [
        ("dense", QuestParams { n_items: 64, ..QuestParams::dense(occ_tx) }),
        ("mid", QuestParams { n_items: 1_024, ..QuestParams::t10_i4(occ_tx) }),
        ("sparse", QuestParams { n_items: 16_384, ..QuestParams::t10_i4(occ_tx) }),
    ];
    println!("\n== container occupancy sweep ({occ_tx} tx per profile) ==");
    let mut out: Vec<(&str, Json)> = Vec::new();
    for (name, params) in profiles {
        let db = QuestGenerator::new(params).generate();
        let block = FlatBlock::from_transactions(&db.transactions, db.n_items);
        let lists = block.tid_lists();
        let n_tx = block.len();

        // 24 items at evenly spaced frequency ranks — representative of
        // the profile's occupancy distribution, not just its head.
        let mut ranked: Vec<usize> = (0..lists.len()).filter(|&i| !lists[i].is_empty()).collect();
        ranked.sort_by_key(|&i| (std::cmp::Reverse(lists[i].len()), i));
        assert!(ranked.len() >= 2, "{name}: degenerate profile");
        let n_sel = 24.min(ranked.len());
        let sel: Vec<usize> = (0..n_sel)
            .map(|r| ranked[r * (ranked.len() - 1) / (n_sel - 1).max(1)])
            .collect();
        let sets: Vec<TidSet> = sel
            .iter()
            .map(|&i| TidSet::from_sorted_tids(&lists[i], n_tx))
            .collect();
        let sel_lists: Vec<&[u32]> = sel.iter().map(|&i| lists[i].as_slice()).collect();
        let dense = DenseRows::build(&sel_lists, n_tx);
        let pairs: Vec<(usize, usize)> = (0..n_sel)
            .flat_map(|a| ((a + 1)..n_sel).map(move |b| (a, b)))
            .collect();

        // Correctness: both representations vs the naive engine oracle.
        let cand: Vec<Itemset> = pairs
            .iter()
            .map(|&(a, b)| {
                let (x, y) = (sel[a] as u32, sel[b] as u32);
                vec![x.min(y), x.max(y)]
            })
            .collect();
        let oracle = build_engine(EngineKind::Naive, None)
            .count(&db.transactions, &cand, db.n_items)
            .unwrap();
        let container_counts: Vec<u64> = pairs
            .iter()
            .map(|&(a, b)| sets[a].intersect_count(&sets[b]))
            .collect();
        let dense_counts: Vec<u64> = pairs.iter().map(|&(a, b)| dense.pair_count(a, b)).collect();
        assert_eq!(container_counts, oracle, "{name}: containers diverge from the oracle");
        assert_eq!(dense_counts, oracle, "{name}: dense rows diverge from the oracle");

        // All nine forced-variant kernel pairings on the two most
        // frequent items (single chunk: every profile fits one).
        let a16: Vec<u16> = lists[ranked[0]].iter().map(|&t| t as u16).collect();
        let b16: Vec<u16> = lists[ranked[1]].iter().map(|&t| t as u16).collect();
        check_kernel_pairings(&a16, &b16, n_tx);

        let time_ms = |f: &mut dyn FnMut()| -> f64 {
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let t0 = Instant::now();
                for _ in 0..reps {
                    f();
                }
                best = best.min(t0.elapsed().as_secs_f64() * 1e3 / reps as f64);
            }
            best
        };
        let container_ms = time_ms(&mut || {
            for &(a, b) in &pairs {
                std::hint::black_box(sets[a].intersect_count(&sets[b]));
            }
        });
        let dense_rows_ms = time_ms(&mut || {
            for &(a, b) in &pairs {
                std::hint::black_box(dense.pair_count(a, b));
            }
        });

        // Residency across the whole (non-empty) inverted index: the
        // cost of keeping either representation resident per split.
        let words = n_tx.div_ceil(64);
        let mut census = ContainerCensus::default();
        let mut container_bytes = 0usize;
        for &i in &ranked {
            let set = TidSet::from_sorted_tids(&lists[i], n_tx);
            census += set.census();
            container_bytes += set.bytes();
        }
        let dense_rows_bytes = ranked.len() * words * 8;

        let wins = container_ms < dense_rows_ms && container_bytes < dense_rows_bytes;
        if name == "sparse" {
            assert!(
                wins,
                "compressed containers must beat dense rows on the sparse profile \
                 ({container_ms:.4} ms vs {dense_rows_ms:.4} ms, \
                 {container_bytes} B vs {dense_rows_bytes} B)"
            );
        }
        println!(
            "{name:>7}: density {:.4} | containers {container_ms:.4} ms, {container_bytes} B \
             | dense rows {dense_rows_ms:.4} ms, {dense_rows_bytes} B \
             | census {}a/{}b/{}r{}",
            block.density(),
            census.arrays,
            census.bitmaps,
            census.runs,
            if wins { " | compressed wins" } else { "" }
        );
        out.push((
            name,
            Json::obj(vec![
                ("n_tx", Json::num(n_tx as f64)),
                ("n_items", Json::num(db.n_items as f64)),
                ("density", Json::num(block.density())),
                ("container_ms", Json::num(container_ms)),
                ("dense_rows_ms", Json::num(dense_rows_ms)),
                ("container_bytes", Json::num(container_bytes as f64)),
                ("dense_rows_bytes", Json::num(dense_rows_bytes as f64)),
                (
                    "census",
                    Json::obj(vec![
                        ("arrays", Json::num(census.arrays as f64)),
                        ("bitmaps", Json::num(census.bitmaps as f64)),
                        ("runs", Json::num(census.runs as f64)),
                    ]),
                ),
                ("counts_match_oracle", Json::Bool(true)),
                ("compressed_wins", Json::Bool(wins)),
            ]),
        ));
    }
    Json::obj(out)
}

//! Ablation A3: the baseline comparison from the paper's reference [8]
//! (Goswami et al.) — classical Apriori vs record-filter vs intersection
//! (tidsets) — plus FP-Growth, on the ~2000-transaction profile [8] used.
//! All four must produce identical frequent itemsets; the comparison is
//! wall time and algorithm-specific work counters across min-support.

use std::time::Instant;

use mr_apriori::prelude::*;

fn time_ms<R>(f: impl Fn() -> R) -> (R, f64) {
    // one warmup, three timed
    let _ = f();
    let iters = 3;
    let t0 = Instant::now();
    let mut out = None;
    for _ in 0..iters {
        out = Some(std::hint::black_box(f()));
    }
    (out.unwrap(), t0.elapsed().as_secs_f64() * 1e3 / iters as f64)
}

fn main() {
    println!("== Ablation A3: baselines on the [8]-style 2k dataset ==\n");
    let db = QuestGenerator::new(QuestParams::goswami_2k()).generate();
    let supports = [0.10f64, 0.07, 0.05, 0.04, 0.03];

    let mut t_classical = Vec::new();
    let mut t_record = Vec::new();
    let mut t_intersection = Vec::new();
    let mut t_fp = Vec::new();
    let mut n_frequent = Vec::new();

    for &ms in &supports {
        let cfg = AprioriConfig { min_support: ms, max_k: 0 };
        let (r_cl, ms_cl) = time_ms(|| ClassicalApriori::default().mine(&db, &cfg));
        let (r_rf, ms_rf) = time_ms(|| RecordFilterApriori.mine(&db, &cfg));
        let (r_in, ms_in) = time_ms(|| IntersectionApriori.mine(&db, &cfg));
        let (r_fp, ms_fp) = time_ms(|| FpGrowth.mine(&db, &cfg));
        assert_eq!(r_cl.frequent, r_rf.frequent, "record-filter differs @ {ms}");
        assert_eq!(r_cl.frequent, r_in.frequent, "intersection differs @ {ms}");
        assert_eq!(r_cl.frequent, r_fp.frequent, "fp-growth differs @ {ms}");
        n_frequent.push(r_cl.frequent.len() as f64);
        t_classical.push(ms_cl);
        t_record.push(ms_rf);
        t_intersection.push(ms_in);
        t_fp.push(ms_fp);
    }

    let mut table = BenchTable::new(
        "A3 — baseline miners, wall ms vs min-support (2k tx, [8]'s setup)",
        "min_support",
        supports.to_vec(),
    );
    table.push_series(Series::new("n_frequent", n_frequent));
    table.push_series(Series::new("classical_ms", t_classical));
    table.push_series(Series::new("record_filter_ms", t_record));
    table.push_series(Series::new("intersection_ms", t_intersection));
    table.push_series(Series::new("fp_growth_ms", t_fp));
    table.emit();
    println!("all four algorithms agree exactly at every support level");
}

//! Ablation: what does the profiling layer cost?
//!
//! The profiler's design claim (DESIGN.md §Profiling & SLOs) is that the
//! *collection* side — span recording with the flight-recorder tee on the
//! sink's hot path, plus the per-level workload sampling in the
//! coordinator — stays within the same 5% wall-clock budget as base
//! observability, and that all analysis cost is paid offline by `repro
//! analyze`, not by the mine. This bench measures three things:
//!
//!  1. plain vs fully profiled mine (trace sink + flight ring + registry),
//!     asserting the <5% overhead budget and byte-identical output;
//!  2. the offline `analyze()` pass over the captured span buffer, so the
//!     "analysis is free at mine time, cheap afterwards" claim has a
//!     number attached;
//!  3. attribution coverage of the captured trace (the CI smoke asserts
//!     the same `>= 0.95` bound on a real trace file).
//!
//! Emits `BENCH_profile.json` (directory override: `BENCH_OUT_DIR`) for
//! the perf-trajectory gate.

use std::sync::Arc;

use mr_apriori::metrics::{measure, Summary};
use mr_apriori::obs::flight::DEFAULT_CAPACITY;
use mr_apriori::obs::profile::{analyze, ParsedSpan};
use mr_apriori::prelude::*;
use mr_apriori::util::json::Json;
use mr_apriori::util::tempdir::TempDir;

const WARMUP: usize = 1;
const RUNS: usize = 7;
const OVERHEAD_BUDGET: f64 = 1.05;

fn driver(apriori: &AprioriConfig) -> MrApriori {
    MrApriori::new(ClusterConfig::fhssc(3), apriori.clone())
        .with_job(JobConfig { n_reducers: 3, ..Default::default() })
        .with_split_tx(500)
}

/// A sink with the flight recorder teed in — the full collection path the
/// profiler adds over bare tracing.
fn profiled_sink(flight_dir: &std::path::Path) -> Arc<TraceSink> {
    let sink = TraceSink::new();
    sink.attach_flight(FlightRecorder::new(flight_dir, DEFAULT_CAPACITY));
    sink
}

fn main() {
    println!("== Ablation: critical-path profiler collection + analysis cost ==\n");
    let db = QuestGenerator::new(QuestParams::t10_i4(4_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };
    let tmp = TempDir::new("ablation_profile_flights");

    // output-invariance first: profiling must not change the answer
    let want = driver(&apriori).mine(&db).expect("plain mine");
    let sink = profiled_sink(tmp.path());
    let got = driver(&apriori)
        .with_trace(Some(TraceCtx::root(Arc::clone(&sink))))
        .with_registry(Arc::new(MetricsRegistry::new()))
        .mine(&db)
        .expect("profiled mine");
    let byte_identical = got.result.frequent == want.result.frequent;
    assert!(byte_identical, "profiling changed the mining output");

    // the captured buffer is what `repro analyze` consumes offline
    let spans: Vec<ParsedSpan> =
        sink.events().iter().map(ParsedSpan::from_event).collect();
    let profile = analyze(&spans).expect("captured trace analyzes");
    let coverage = profile.coverage();
    assert!(
        coverage >= 0.95,
        "attribution coverage {coverage:.3} below the 0.95 bound"
    );

    let plain = measure(WARMUP, RUNS, || {
        driver(&apriori).mine(&db).expect("plain mine");
    });
    // fresh sink + ring per iteration: steady-state tee cost, not one
    // giant buffer amortised across runs
    let profiled = measure(WARMUP, RUNS, || {
        driver(&apriori)
            .with_trace(Some(TraceCtx::root(profiled_sink(tmp.path()))))
            .with_registry(Arc::new(MetricsRegistry::new()))
            .mine(&db)
            .expect("profiled mine");
    });
    let analysis = measure(WARMUP, RUNS, || {
        analyze(&spans).expect("captured trace analyzes");
    });

    let overhead = profiled.median / plain.median.max(1e-9);
    let under_budget = overhead < OVERHEAD_BUDGET;

    println!("config   | median(ms) | p95(ms) | p99(ms) | mean(ms)");
    for (name, s) in [("plain", &plain), ("profiled", &profiled), ("analyze", &analysis)] {
        println!(
            "{:>8} | {:>10.2} | {:>7.2} | {:>7.2} | {:>8.2}",
            name,
            s.median * 1e3,
            s.p95 * 1e3,
            s.p99 * 1e3,
            s.mean * 1e3
        );
    }
    println!(
        "\nprofiling overhead: {:.2}% on the median ({} spans, coverage {:.3}); budget {:.0}%",
        (overhead - 1.0) * 100.0,
        spans.len(),
        coverage,
        (OVERHEAD_BUDGET - 1.0) * 100.0,
    );
    assert!(
        under_budget,
        "profiling overhead {overhead:.3}x exceeds the {OVERHEAD_BUDGET}x budget"
    );

    let mut table = BenchTable::new(
        "Ablation: profiler collection + offline analysis (T10.I4 4k, fhssc/3)",
        "config",
        vec![0.0, 1.0, 2.0],
    );
    table.push_series(Series::new(
        "median_ms",
        vec![plain.median * 1e3, profiled.median * 1e3, analysis.median * 1e3],
    ));
    table.push_series(Series::new(
        "p99_ms",
        vec![plain.p99 * 1e3, profiled.p99 * 1e3, analysis.p99 * 1e3],
    ));
    table.emit();

    let summary_json = |s: &Summary| {
        Json::obj(vec![
            ("n", Json::num(s.n as f64)),
            ("median_ms", Json::num(s.median * 1e3)),
            ("p95_ms", Json::num(s.p95 * 1e3)),
            ("p99_ms", Json::num(s.p99 * 1e3)),
            ("mean_ms", Json::num(s.mean * 1e3)),
            ("min_ms", Json::num(s.min * 1e3)),
            ("max_ms", Json::num(s.max * 1e3)),
        ])
    };
    let doc = Json::obj(vec![
        ("plain", summary_json(&plain)),
        ("profiled", summary_json(&profiled)),
        ("analyze", summary_json(&analysis)),
        ("overhead_ratio", Json::num(overhead)),
        (
            "speedup_plain_vs_profiled",
            Json::num(plain.median / profiled.median.max(1e-9)),
        ),
        ("overhead_under_budget", Json::Bool(under_budget)),
        ("byte_identical", Json::Bool(byte_identical)),
        ("coverage", Json::num(coverage)),
        ("coverage_at_least_095", Json::Bool(coverage >= 0.95)),
        ("n_trace_events", Json::num(spans.len() as f64)),
    ]);
    let out_dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_profile.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_profile.json");
    println!("\nwrote {}", path.display());
}

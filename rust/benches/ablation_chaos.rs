//! Ablation: what node-loss recovery costs, and that it changes nothing.
//!
//! The fault-tolerance claim (DESIGN.md §Fault tolerance) is Hadoop's:
//! losing a tasktracker mid-job re-queues its running attempts *and* its
//! completed map outputs onto survivors, the namenode re-replicates the
//! lost blocks, and the level-wise driver resumes from the last
//! completed level — with the mined output byte-identical to a
//! fault-free run. This bench injects deterministic fault plans through
//! the chaos harness and measures the recovery overhead each kind
//! charges:
//!
//! * **fault-free baseline** vs a mid-mine node kill, a kill plus a
//!   degraded straggler, and a shuffle fetch-failure storm — wall-clock
//!   per scenario, with every result asserted byte-identical;
//! * **transient store I/O** during a snapshot commit — the bounded
//!   retry path vs a clean publish.
//!
//! Results land in `BENCH_chaos.json` (directory override:
//! `BENCH_OUT_DIR`): per-scenario wall-clock and recovery counters, the
//! `recovery_efficiency` ratio the perf gate tracks, and the
//! byte-identity flags the gate exact-matches.

use std::sync::Arc;
use std::time::Instant;

use mr_apriori::prelude::*;
use mr_apriori::util::json::Json;
use mr_apriori::util::tempdir::TempDir;

const MIN_CONF: f64 = 0.5;

fn driver(apriori: &AprioriConfig) -> MrApriori {
    MrApriori::new(ClusterConfig::fhssc(3), apriori.clone())
        .with_job(JobConfig { n_reducers: 3, ..Default::default() })
        .with_split_tx(300)
}

struct Scenario {
    name: &'static str,
    plan: &'static str,
}

fn main() {
    println!("== Ablation: node-loss recovery overhead (chaos harness) ==\n");
    let db = QuestGenerator::new(QuestParams::t10_i4(3_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };

    // -- fault-free baseline --
    let t = Instant::now();
    let clean = driver(&apriori).mine(&db).expect("fault-free mine");
    let clean_secs = t.elapsed().as_secs_f64();
    println!(
        "fault-free: {} frequent itemsets in {clean_secs:.3}s",
        clean.result.frequent.len()
    );

    let scenarios = [
        Scenario { name: "kill_mid_mine", plan: "kill:1@level:2" },
        Scenario { name: "kill_plus_straggler", plan: "kill:2@level:2;slow:0:4@now" },
        Scenario {
            name: "fetch_storm",
            plan: "fetchfail:0:2@now;fetchfail:1:2@now;fetchfail:2:2@level:2",
        },
        Scenario { name: "kill_at_map_wave", plan: "kill:0@maps:4" },
    ];

    println!("\nscenario            | wall(s) | overhead | lost maps | fetch retries | identical");
    let mut rows = Vec::new();
    let mut all_identical = true;
    for sc in &scenarios {
        let clock = Arc::new(FaultClock::new(FaultPlan::parse(sc.plan).expect(sc.plan)));
        let t = Instant::now();
        let report = driver(&apriori)
            .with_chaos(Some(Arc::clone(&clock)))
            .mine(&db)
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        let secs = t.elapsed().as_secs_f64();
        let identical = report.result.frequent == clean.result.frequent;
        all_identical &= identical;
        let lost_maps: usize = report.jobs.iter().map(|(_, s)| s.lost_maps_requeued).sum();
        let retries: usize = report.jobs.iter().map(|(_, s)| s.shuffle_fetch_retries).sum();
        let reexec: usize = report.jobs.iter().map(|(_, s)| s.maps_reexecuted).sum();
        let overhead = secs / clean_secs.max(1e-9);
        println!(
            "{:<19} | {:>7.3} | {:>7.2}x | {:>9} | {:>13} | {}",
            sc.name, secs, overhead, lost_maps, retries, identical
        );
        rows.push((sc, secs, overhead, lost_maps, retries, reexec, identical, clock));
    }
    assert!(all_identical, "a fault plan changed the mined output");

    // the gate tracks recovery efficiency for the plain node-kill case:
    // fault-free wall over chaotic wall (1.0 = free recovery)
    let kill = &rows[0];
    let recovery_efficiency = clean_secs / kill.1.max(1e-9);

    // -- transient store I/O: bounded retry vs clean publish --
    let tmp = TempDir::new("chaos_bench");
    let index = RuleIndex::build(&clean.result, MIN_CONF);
    let snap = |generation| SnapshotRef {
        generation,
        base: BaseRef::of(&db),
        min_support: apriori.min_support,
        max_k: apriori.max_k,
        delta: &[],
        result: &clean.result,
        state: None,
        index: &index,
    };
    let clean_store = SnapshotStore::open(tmp.path().join("clean"), 4).expect("open");
    let t = Instant::now();
    clean_store.publish(&snap(0)).expect("clean publish");
    let clean_publish_secs = t.elapsed().as_secs_f64();

    let store_clock = Arc::new(FaultClock::new(FaultPlan::parse("storeio:3@now").unwrap()));
    let faulted_store = SnapshotStore::open(tmp.path().join("faulted"), 4)
        .expect("open")
        .with_chaos(Arc::clone(&store_clock));
    let t = Instant::now();
    faulted_store.publish(&snap(0)).expect("publish rides out transient I/O errors");
    let faulted_publish_secs = t.elapsed().as_secs_f64();
    let store_recovered = store_clock.stats().store_faults == 3;
    assert!(store_recovered, "the injected store faults never fired");
    println!(
        "\nsnapshot publish: clean {:.3}s vs 3 injected I/O errors {:.3}s (retry backoff)",
        clean_publish_secs, faulted_publish_secs
    );

    let mut table = BenchTable::new(
        "Ablation: recovery overhead by fault scenario (T10.I4 3k, fhssc/3)",
        "scenario",
        (1..=rows.len()).map(|i| i as f64).collect(),
    );
    table.push_series(Series::new("wall_ms", rows.iter().map(|r| r.1 * 1e3).collect()));
    table.push_series(Series::new("overhead_x", rows.iter().map(|r| r.2).collect()));
    table.emit();

    let doc = Json::obj(vec![
        ("faultfree_wall_ms", Json::num(clean_secs * 1e3)),
        ("recovery_efficiency", Json::num(recovery_efficiency)),
        ("all_byte_identical", Json::Bool(all_identical)),
        (
            "scenarios",
            Json::Arr(
                rows.iter()
                    .map(|(sc, secs, overhead, lost_maps, retries, reexec, identical, clock)| {
                        let cs = clock.stats();
                        Json::obj(vec![
                            ("name", Json::str(sc.name)),
                            ("plan", Json::str(sc.plan)),
                            ("wall_ms", Json::num(secs * 1e3)),
                            ("overhead_x", Json::num(*overhead)),
                            ("byte_identical", Json::Bool(*identical)),
                            ("faults_injected", Json::num(cs.faults_injected as f64)),
                            ("nodes_killed", Json::num(cs.nodes_killed as f64)),
                            ("lost_maps_requeued", Json::num(*lost_maps as f64)),
                            ("shuffle_fetch_retries", Json::num(*retries as f64)),
                            ("maps_reexecuted", Json::num(*reexec as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "store_retry",
            Json::obj(vec![
                ("recovered", Json::Bool(store_recovered)),
                ("injected_faults", Json::num(3.0)),
                ("clean_publish_ms", Json::num(clean_publish_secs * 1e3)),
                ("faulted_publish_ms", Json::num(faulted_publish_secs * 1e3)),
            ]),
        ),
    ]);
    let out_dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_chaos.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_chaos.json");
    println!("\nwrote {}", path.display());

    println!(
        "every fault scenario mined byte-identically on the survivors \
         (recovery efficiency {recovery_efficiency:.2} for a mid-mine kill)"
    );
}

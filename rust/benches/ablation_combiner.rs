//! Ablation A2: the map-side combiner. Identical results, less shuffle:
//! measures shuffle records and end-to-end wall time with the combiner on
//! and off across transaction volumes, on real multi-threaded execution.

use mr_apriori::prelude::*;

fn main() {
    println!("== Ablation A2: combiner on/off ==\n");
    let volumes = [1_000usize, 2_000, 4_000];
    let apriori = AprioriConfig { min_support: 0.02, max_k: 2 };
    let cluster = ClusterConfig::fhssc(3);

    let mut shuffle_on = Vec::new();
    let mut shuffle_off = Vec::new();
    let mut shuffle_l1_on = Vec::new();
    let mut shuffle_l1_off = Vec::new();
    let mut wall_on = Vec::new();
    let mut wall_off = Vec::new();

    for &v in &volumes {
        let db = QuestGenerator::new(QuestParams::t10_i4(v)).generate();
        let run = |combine: bool| {
            let job = JobConfig {
                enable_combiner: combine,
                n_reducers: 3,
                ..Default::default()
            };
            MrApriori::new(cluster.clone(), apriori.clone())
                .with_job(job)
                .with_split_tx(250)
                .mine(&db)
                .expect("run")
        };
        let on = run(true);
        let off = run(false);
        assert_eq!(
            on.result.frequent, off.result.frequent,
            "combiner must not change results"
        );
        // Per-level split: the combiner's win is on the level-1 job
        // (item counting emits one record per item occurrence); level-2+
        // map output is already aggregated per split by the engine.
        let l1 = |r: &RunReport| r.jobs.iter().find(|(k, _)| *k == 1).unwrap().1.shuffle_records as f64;
        shuffle_l1_on.push(l1(&on));
        shuffle_l1_off.push(l1(&off));
        shuffle_on.push(on.jobs.iter().map(|(_, s)| s.shuffle_records).sum::<usize>() as f64);
        shuffle_off.push(off.jobs.iter().map(|(_, s)| s.shuffle_records).sum::<usize>() as f64);
        wall_on.push(on.wall_secs);
        wall_off.push(off.wall_secs);
    }

    let mut table = BenchTable::new(
        "A2 — combiner ablation (3-node FHSSC, real execution)",
        "transactions",
        volumes.iter().map(|&v| v as f64).collect(),
    );
    table.push_series(Series::new("shuffle_records_on", shuffle_on.clone()));
    table.push_series(Series::new("shuffle_records_off", shuffle_off.clone()));
    table.push_series(Series::new("shuffle_L1_on", shuffle_l1_on.clone()));
    table.push_series(Series::new("shuffle_L1_off", shuffle_l1_off.clone()));
    table.push_series(Series::new("wall_s_on", wall_on));
    table.push_series(Series::new("wall_s_off", wall_off));
    table.emit();

    for i in 0..volumes.len() {
        assert!(
            shuffle_l1_on[i] * 2.0 < shuffle_l1_off[i],
            "combiner must cut the L1 shuffle >2x at {} tx: {} vs {}",
            volumes[i],
            shuffle_l1_on[i],
            shuffle_l1_off[i]
        );
        assert!(
            shuffle_on[i] < shuffle_off[i],
            "combiner must reduce total shuffle at {} tx",
            volumes[i]
        );
    }
    println!("shape checks passed: identical results, >2x L1 shuffle reduction");
}

//! Ablation: cold re-mine vs warm restore, and what persistence costs.
//!
//! The durable-store claim is that a restarted server answers its first
//! query after one file read + decode instead of a full re-mine of the
//! stable database (the redundant-rescan cost Singh et al. attribute
//! most Hadoop-Apriori wall-clock to). This bench measures:
//!
//! * **time-to-first-query**: cold (capture-mine + index build + first
//!   answer) vs warm (open store + decode newest generation + first
//!   answer), with the warm answer asserted byte-identical;
//! * **snapshot write overhead per refresh cycle**: the same
//!   incremental refresh sequence with and without a store attached,
//!   with per-cycle wall-clock, committed bytes, and the inline
//!   assertion that both publish byte-identical snapshots.
//!
//! Results land in `BENCH_restart.json` (directory override:
//! `BENCH_OUT_DIR`) — cold/warm TTFQ, speedup, bytes per cycle — so the
//! restart-path trajectory is tracked per push like the engine ablation.

use std::sync::Arc;
use std::time::Instant;

use mr_apriori::prelude::*;
use mr_apriori::util::json::Json;
use mr_apriori::util::tempdir::TempDir;

const MIN_CONF: f64 = 0.5;
const REFRESH_CYCLES: u64 = 3;
const DELTA_TX: usize = 200;

fn driver(apriori: &AprioriConfig) -> MrApriori {
    MrApriori::new(ClusterConfig::fhssc(3), apriori.clone())
        .with_job(JobConfig { n_reducers: 3, ..Default::default() })
        .with_split_tx(500)
}

fn main() {
    println!("== Ablation: cold re-mine vs warm restore (durable snapshot store) ==\n");
    let tmp = TempDir::new("restart_bench");
    let dir = tmp.path();

    let db = QuestGenerator::new(QuestParams::t10_i4(4_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };

    // -- cold start: capture-mine + index build + first answer --
    let t_cold = Instant::now();
    let (report, state) = MinedState::capture(&driver(&apriori), &db).expect("cold mine");
    let index = RuleIndex::build(&report.result, MIN_CONF);
    let singles: Vec<u32> = report.result.level(1).map(|(is, _)| is[0]).collect();
    assert!(!singles.is_empty(), "nothing frequent at this support");
    let probe: Vec<u32> = singles.iter().copied().take(2).collect();
    let cold_answer = render_lines(&index.recommend(&probe, 5));
    let cold_ttfq = t_cold.elapsed().as_secs_f64();

    // persist generation 0 — what `repro mine --store-dir` writes
    let store = Arc::new(SnapshotStore::open(dir, 8).expect("open store"));
    let t_persist = Instant::now();
    store
        .publish(&SnapshotRef {
            generation: 0,
            base: BaseRef::of(&db),
            min_support: apriori.min_support,
            max_k: apriori.max_k,
            delta: &[],
            result: &report.result,
            state: Some(&state),
            index: &index,
        })
        .expect("publish generation 0");
    let persist0_secs = t_persist.elapsed().as_secs_f64();
    let gen0_bytes = store.bytes_written();

    // -- warm restart: open + decode + first answer, zero mining --
    let t_warm = Instant::now();
    let reopened = SnapshotStore::open(dir, 8).expect("reopen store");
    let mut warm_db = db.clone(); // stands in for re-reading the base .dat
    let resumed = resume_serving(&reopened, &mut warm_db, BaseRef::of(&db))
        .expect("load")
        .expect("generation 0 on disk");
    let warm_answer = render_lines(&resumed.cell.load().recommend(&probe, 5));
    let warm_ttfq = t_warm.elapsed().as_secs_f64();

    assert_eq!(warm_answer, cold_answer, "warm restore must serve byte-identically");
    assert_eq!(resumed.result.frequent, report.result.frequent);
    assert!(
        warm_ttfq < cold_ttfq,
        "warm restore ({warm_ttfq:.3}s) must beat a cold re-mine ({cold_ttfq:.3}s)"
    );
    println!(
        "time-to-first-query: cold {:.3}s (mine+build) vs warm {:.3}s (restore) — {:.1}x; \
         gen-0 snapshot {} bytes, committed in {:.3}s",
        cold_ttfq,
        warm_ttfq,
        cold_ttfq / warm_ttfq.max(1e-9),
        gen0_bytes,
        persist0_secs,
    );

    // -- snapshot write overhead per incremental refresh cycle --
    let guard = IncrementalConfig { enabled: true, max_frontier_blowup: 1e9 };
    let plain = Refresher::new(driver(&apriori), MIN_CONF).with_incremental(guard.clone());
    plain.seed_state(state.clone());
    let stored = Refresher::new(driver(&apriori), MIN_CONF)
        .with_incremental(guard)
        .with_store(Arc::clone(&store), BaseRef::of(&db), db.len());
    stored.seed_state(state);
    let mut plain_db = db.clone();
    let mut stored_db = db.clone();
    let plain_cell = SnapshotCell::new(Arc::new(RuleIndex::build(&report.result, MIN_CONF)));
    let stored_cell = SnapshotCell::new(Arc::new(RuleIndex::build(&report.result, MIN_CONF)));

    println!("\ncycle | plain(s) | +store(s) | snapshot bytes");
    let mut rows: Vec<(u64, f64, f64, u64)> = Vec::new();
    for cycle in 0..REFRESH_CYCLES {
        let delta = synth_delta(DELTA_TX, db.n_items, 0x5EED + cycle);

        let t = Instant::now();
        plain
            .refresh_once(&mut plain_db, delta.clone(), &plain_cell)
            .expect("plain refresh");
        let plain_secs = t.elapsed().as_secs_f64();

        let bytes_before = store.bytes_written();
        let t = Instant::now();
        stored
            .refresh_once(&mut stored_db, delta, &stored_cell)
            .expect("persisted refresh");
        let stored_secs = t.elapsed().as_secs_f64();
        let cycle_bytes = store.bytes_written() - bytes_before;

        // persistence must not change what gets served
        let a = render_lines(&plain_cell.load().recommend(&probe, 5));
        let b = render_lines(&stored_cell.load().recommend(&probe, 5));
        assert_eq!(a, b, "cycle {cycle}: persisted refresh diverged");

        println!("{:>5} | {:>8.3} | {:>9.3} | {:>14}", cycle + 1, plain_secs, stored_secs, cycle_bytes);
        rows.push((cycle + 1, plain_secs, stored_secs, cycle_bytes));
    }

    // the store now holds gen 0 + one generation per cycle, and a kill
    // right now would warm-restart at the last one
    let final_snap = reopened.load_latest().expect("scan").expect("latest");
    assert_eq!(final_snap.generation, REFRESH_CYCLES);
    assert_eq!(final_snap.result.n_transactions, stored_db.len());

    let mut table = BenchTable::new(
        "Ablation: snapshot persistence overhead per refresh cycle (T10.I4 4k base)",
        "cycle",
        rows.iter().map(|r| r.0 as f64).collect(),
    );
    table.push_series(Series::new(
        "plain_ms",
        rows.iter().map(|r| r.1 * 1e3).collect(),
    ));
    table.push_series(Series::new(
        "persisted_ms",
        rows.iter().map(|r| r.2 * 1e3).collect(),
    ));
    table.push_series(Series::new(
        "snapshot_bytes",
        rows.iter().map(|r| r.3 as f64).collect(),
    ));
    table.emit();

    let doc = Json::obj(vec![
        ("cold_ttfq_ms", Json::num(cold_ttfq * 1e3)),
        ("warm_ttfq_ms", Json::num(warm_ttfq * 1e3)),
        ("warm_speedup", Json::num(cold_ttfq / warm_ttfq.max(1e-9))),
        ("gen0_snapshot_bytes", Json::num(gen0_bytes as f64)),
        ("gen0_persist_ms", Json::num(persist0_secs * 1e3)),
        (
            "cycles",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("cycle", Json::num(r.0 as f64)),
                            ("plain_ms", Json::num(r.1 * 1e3)),
                            ("persisted_ms", Json::num(r.2 * 1e3)),
                            ("snapshot_bytes", Json::num(r.3 as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let out_dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_restart.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_restart.json");
    println!("\nwrote {}", path.display());

    println!(
        "warm restore served byte-identical answers at every checkpoint; \
         kill-now recovery would resume at generation {}",
        REFRESH_CYCLES
    );
}

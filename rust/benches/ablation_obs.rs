//! Ablation: what does observability cost?
//!
//! The tracing/metrics design claim (DESIGN.md §Observability) is that a
//! fully instrumented mine — span tree, Hadoop-style task counters, the
//! metrics registry — stays within a 5% wall-clock budget of the
//! uninstrumented path, because the off path is one `Option` branch and
//! the on path only appends to thread-local span buffers plus relaxed
//! atomics. This bench measures both configurations over repeated runs,
//! asserts the budget *and* that instrumentation is output-invariant
//! (byte-identical frequent itemsets), and emits `BENCH_obs.json`
//! (directory override: `BENCH_OUT_DIR`) for the perf-trajectory gate.
//!
//! The table reports median, p95 and p99 per configuration — the tail
//! columns exist so a tracing overhead that only bites the slowest runs
//! (lock contention on the sink, say) still shows up.

use std::sync::Arc;

use mr_apriori::metrics::{measure, Summary};
use mr_apriori::prelude::*;
use mr_apriori::util::json::Json;

const WARMUP: usize = 1;
const RUNS: usize = 7;
const OVERHEAD_BUDGET: f64 = 1.05;

fn driver(apriori: &AprioriConfig) -> MrApriori {
    MrApriori::new(ClusterConfig::fhssc(3), apriori.clone())
        .with_job(JobConfig { n_reducers: 3, ..Default::default() })
        .with_split_tx(500)
}

fn main() {
    println!("== Ablation: tracing + metrics overhead on the mining path ==\n");
    let db = QuestGenerator::new(QuestParams::t10_i4(4_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };

    // output-invariance first: instrumentation must not change the answer
    let want = driver(&apriori).mine(&db).expect("plain mine");
    let sink = TraceSink::new();
    let registry = Arc::new(MetricsRegistry::new());
    let got = driver(&apriori)
        .with_trace(Some(TraceCtx::root(Arc::clone(&sink))))
        .with_registry(Arc::clone(&registry))
        .mine(&db)
        .expect("instrumented mine");
    let byte_identical = got.result.frequent == want.result.frequent;
    assert!(byte_identical, "instrumentation changed the mining output");
    let n_trace_events = sink.len();
    assert!(n_trace_events > 0, "instrumented mine recorded no spans");

    let plain = measure(WARMUP, RUNS, || {
        driver(&apriori).mine(&db).expect("plain mine");
    });
    // a fresh sink per iteration: steady-state recording cost, not the
    // cost of growing one giant buffer across runs
    let traced = measure(WARMUP, RUNS, || {
        let sink = TraceSink::new();
        driver(&apriori)
            .with_trace(Some(TraceCtx::root(sink)))
            .with_registry(Arc::new(MetricsRegistry::new()))
            .mine(&db)
            .expect("instrumented mine");
    });

    let overhead = traced.median / plain.median.max(1e-9);
    let under_budget = overhead < OVERHEAD_BUDGET;

    println!("config | median(ms) | p95(ms) | p99(ms) | mean(ms)");
    for (name, s) in [("plain", &plain), ("traced", &traced)] {
        println!(
            "{:>6} | {:>10.1} | {:>7.1} | {:>7.1} | {:>8.1}",
            name,
            s.median * 1e3,
            s.p95 * 1e3,
            s.p99 * 1e3,
            s.mean * 1e3
        );
    }
    println!(
        "\ntracing overhead: {:.2}% on the median ({} spans per run); budget {:.0}%",
        (overhead - 1.0) * 100.0,
        n_trace_events,
        (OVERHEAD_BUDGET - 1.0) * 100.0,
    );
    assert!(
        under_budget,
        "tracing overhead {overhead:.3}x exceeds the {OVERHEAD_BUDGET}x budget"
    );

    let mut table = BenchTable::new(
        "Ablation: observability overhead (T10.I4 4k, fhssc/3)",
        "config",
        vec![0.0, 1.0],
    );
    table.push_series(Series::new(
        "median_ms",
        vec![plain.median * 1e3, traced.median * 1e3],
    ));
    table.push_series(Series::new(
        "p95_ms",
        vec![plain.p95 * 1e3, traced.p95 * 1e3],
    ));
    table.push_series(Series::new(
        "p99_ms",
        vec![plain.p99 * 1e3, traced.p99 * 1e3],
    ));
    table.emit();

    let summary_json = |s: &Summary| {
        Json::obj(vec![
            ("n", Json::num(s.n as f64)),
            ("median_ms", Json::num(s.median * 1e3)),
            ("p95_ms", Json::num(s.p95 * 1e3)),
            ("p99_ms", Json::num(s.p99 * 1e3)),
            ("mean_ms", Json::num(s.mean * 1e3)),
            ("min_ms", Json::num(s.min * 1e3)),
            ("max_ms", Json::num(s.max * 1e3)),
        ])
    };
    let doc = Json::obj(vec![
        ("plain", summary_json(&plain)),
        ("traced", summary_json(&traced)),
        ("overhead_ratio", Json::num(overhead)),
        (
            "speedup_plain_vs_traced",
            Json::num(plain.median / traced.median.max(1e-9)),
        ),
        ("overhead_under_budget", Json::Bool(under_budget)),
        ("byte_identical", Json::Bool(byte_identical)),
        ("n_trace_events", Json::num(n_trace_events as f64)),
    ]);
    let out_dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_obs.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_obs.json");
    println!("\nwrote {}", path.display());
}

//! Ablation: incremental refresh (FUP-style border maintenance) vs full
//! re-mine, over a delta-size sweep.
//!
//! One Quest T10.I4 base generation is capture-mined into a
//! [`MinedState`]; then each delta in the sweep is folded in twice —
//! once through `apply_delta` (one counting job over Δ plus targeted
//! scans for the promoted frontier) and once through a from-scratch
//! `MrApriori::mine` of the same union database. The differential
//! assertion (identical frequent itemsets + supports at every
//! generation) runs inline; the table reports wall-clock for both paths
//! and, for the incremental one, how many itemsets were re-counted
//! against the full database (the frontier) vs merely delta-scanned —
//! the number that must stay ≪ the frequent-set size for small deltas.

use std::time::Instant;

use mr_apriori::incremental::verify_invariant;
use mr_apriori::prelude::*;

const DELTA_SIZES: [usize; 3] = [40, 200, 1000];

fn main() {
    println!("== Ablation: incremental (border maintenance) vs full re-mine ==\n");
    let mut db = QuestGenerator::new(QuestParams::t10_i4(4_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };
    let driver = MrApriori::new(ClusterConfig::fhssc(3), apriori.clone())
        .with_job(JobConfig { n_reducers: 3, ..Default::default() })
        .with_split_tx(500);
    let guard = IncrementalConfig { enabled: true, max_frontier_blowup: 1.0 };

    let t0 = Instant::now();
    let (report0, mut state) = MinedState::capture(&driver, &db).expect("base capture");
    let capture_secs = t0.elapsed().as_secs_f64();
    println!(
        "base generation: {} tx, {} frequent itemsets + {} border tracked \
         (capture mine {capture_secs:.3}s)",
        db.len(),
        state.n_frequent(),
        state.n_border(),
    );
    assert_eq!(report0.result.frequent, state.to_result().frequent);

    let mut rows = Vec::new();
    for (i, &delta_tx) in DELTA_SIZES.iter().enumerate() {
        let delta = synth_delta(delta_tx, db.n_items, 0xD117A + i as u64);
        db.append(delta.clone());

        let t_inc = Instant::now();
        let outcome = state
            .apply_delta(&driver, &db, &delta, &guard)
            .expect("incremental apply");
        let incr_secs = t_inc.elapsed().as_secs_f64();
        let stats = match outcome {
            DeltaApply::Applied(stats) => stats,
            DeltaApply::FrontierBlowup { frontier, tracked } => {
                // Guarded fallback: re-capture so the sweep continues,
                // and record the frontier that tripped it.
                println!(
                    "delta {delta_tx}: frontier blowup ({frontier} > {tracked} tracked), \
                     fell back to full capture"
                );
                let (_, fresh) = MinedState::capture(&driver, &db).expect("fallback capture");
                state = fresh;
                DeltaStats {
                    delta_tx,
                    tracked,
                    frontier_recounted: frontier,
                    ..Default::default()
                }
            }
        };

        let t_full = Instant::now();
        let full = driver.mine(&db).expect("full re-mine");
        let full_secs = t_full.elapsed().as_secs_f64();

        // the differential point: byte-identical state at every generation
        assert_eq!(
            state.to_result().frequent,
            full.result.frequent,
            "delta {delta_tx}: incremental state diverged from full re-mine"
        );
        verify_invariant(&state, &db).expect("border invariant");

        let n_frequent = state.n_frequent();
        println!(
            "delta {:>5} tx -> {:>5} tx: incremental {:.3}s vs full {:.3}s \
             ({} delta-scanned, {} full-db recounts, +{} promoted, -{} demoted, \
             {} frequent)",
            delta_tx,
            db.len(),
            incr_secs,
            full_secs,
            stats.tracked,
            stats.frontier_recounted,
            stats.promoted,
            stats.demoted,
            n_frequent,
        );
        rows.push((delta_tx, incr_secs, full_secs, stats, n_frequent));
    }

    // small deltas must re-count (against the full db) far fewer itemsets
    // than the frequent set they maintain — the whole point of the border
    let (small_delta, _, _, small_stats, small_frequent) = &rows[0];
    assert!(
        small_stats.frontier_recounted < *small_frequent,
        "delta {small_delta}: {} full-db recounts vs {} frequent itemsets — \
         incremental refresh recounted too much",
        small_stats.frontier_recounted,
        small_frequent,
    );

    let mut table = BenchTable::new(
        "Ablation: incremental vs full re-mine per delta (T10.I4 4k base)",
        "delta_tx",
        rows.iter().map(|r| r.0 as f64).collect(),
    );
    let series: [(&str, Vec<f64>); 5] = [
        ("incremental_ms", rows.iter().map(|r| r.1 * 1e3).collect()),
        ("full_remine_ms", rows.iter().map(|r| r.2 * 1e3).collect()),
        ("delta_scanned", rows.iter().map(|r| r.3.tracked as f64).collect()),
        (
            "fulldb_recounts",
            rows.iter().map(|r| r.3.frontier_recounted as f64).collect(),
        ),
        ("n_frequent", rows.iter().map(|r| r.4 as f64).collect()),
    ];
    for (name, values) in series {
        table.push_series(Series::new(name, values));
    }
    table.emit();
    println!(
        "\nall {} generations byte-identical to full re-mine; border invariant held \
         throughout",
        rows.len(),
    );
}

//! Ablation A5: level-wise MR Apriori (the paper's design — one job per
//! level) vs the SON/partition two-job design (the "future work"
//! extension). Same results required; compares job counts, simulated
//! makespan (job startup dominates shallow workloads) and real wall time.

use mr_apriori::apriori::son::SonApriori;
use mr_apriori::prelude::*;

fn main() {
    println!("== Ablation A5: level-wise vs SON (two-job) ==\n");
    let volumes = [1_000usize, 2_000, 4_000];
    let cfg = AprioriConfig { min_support: 0.02, max_k: 3 };
    let cluster = ClusterConfig::fhssc(3);

    let mut jobs_lw = Vec::new();
    let mut jobs_son = Vec::new();
    let mut wall_lw = Vec::new();
    let mut wall_son = Vec::new();
    let mut startup_saving = Vec::new();

    for &v in &volumes {
        let db = QuestGenerator::new(QuestParams::t10_i4(v)).generate();
        let t0 = std::time::Instant::now();
        let lw = MrApriori::new(cluster.clone(), cfg.clone())
            .with_split_tx(250)
            .mine(&db)
            .expect("level-wise");
        let t_lw = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let son = SonApriori::new(cluster.clone(), cfg.clone())
            .with_split_tx(250)
            .mine(&db)
            .expect("son");
        let t_son = t0.elapsed().as_secs_f64();
        assert_eq!(
            lw.result.frequent, son.result.frequent,
            "SON must be exact at {v} tx"
        );
        jobs_lw.push(lw.jobs.len() as f64);
        jobs_son.push(2.0);
        wall_lw.push(t_lw);
        wall_son.push(t_son);
        // Each saved job skips one startup+coordination round in the
        // simulated deployment (the dominant cost on the paper's testbed).
        let per_job_overhead = 4.0 + 2.0 * (cluster.n_nodes() as f64).ln();
        startup_saving.push((lw.jobs.len() as f64 - 2.0) * per_job_overhead);
    }

    let mut table = BenchTable::new(
        "A5 — level-wise (paper) vs SON two-job design",
        "transactions",
        volumes.iter().map(|&v| v as f64).collect(),
    );
    table.push_series(Series::new("jobs_levelwise", jobs_lw.clone()));
    table.push_series(Series::new("jobs_son", jobs_son));
    table.push_series(Series::new("wall_s_levelwise", wall_lw));
    table.push_series(Series::new("wall_s_son", wall_son));
    table.push_series(Series::new("sim_startup_saved_s", startup_saving.clone()));
    table.emit();

    assert!(jobs_lw.iter().all(|&j| j > 2.0), "level-wise needs >2 jobs");
    assert!(startup_saving.iter().all(|&s| s > 0.0));
    println!("shape checks passed: SON exact with 2 jobs vs {jobs_lw:?}");
}

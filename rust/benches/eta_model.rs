//! §4 η-model reproduction: the paper states η = FHDSC/FHSSC with
//! "FHDSC = FHSSC = ln N". Taken literally that makes η ≡ 1, which
//! contradicts its own fig 4 (FHDSC is slower). This bench measures:
//!
//!   1. η(N) from the simulator (the fig-4 ratio);
//!   2. the heterogeneity model `EtaModel::eta_predicted` overlay;
//!   3. the ln N *coordination-overhead* reading: fit a + b·ln N to the
//!      measured startup overhead and report the recovered coefficient.

use mr_apriori::coordinator;
use mr_apriori::prelude::*;

fn main() {
    println!("== η model: FHDSC/FHSSC vs ln N ==\n");
    let db = QuestGenerator::new(QuestParams::t10_i4(4_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };
    let report = MrApriori::new(ClusterConfig::fhssc(3), apriori)
        .with_split_tx(250)
        .mine(&db)
        .expect("profiling run");

    let ns: Vec<usize> = vec![2, 3, 4, 6, 8, 12, 16, 24, 32];
    let job = JobConfig::default();
    let model = EtaModel::default();

    let mut eta_meas = Vec::new();
    let mut eta_pred = Vec::new();
    let mut startup = Vec::new();
    for &n in &ns {
        let hom = coordinator::simulate(&ClusterConfig::fhssc(n), &report.profile, 250, &job);
        let het = coordinator::simulate(&ClusterConfig::fhdsc(n), &report.profile, 250, &job);
        eta_meas.push(het.total_secs / hom.total_secs);
        eta_pred.push(model.eta_predicted(n));
        startup.push(hom.startup_secs);
    }

    let mut table = BenchTable::new(
        "η = FHDSC/FHSSC vs cluster size",
        "nodes",
        ns.iter().map(|&n| n as f64).collect(),
    );
    table.push_series(Series::new("eta_measured", eta_meas.clone()));
    table.push_series(Series::new("eta_hetero_model", eta_pred.clone()));
    table.push_series(Series::new(
        "eta_paper_literal",
        ns.iter().map(|&n| EtaModel::eta_paper_literal(n)).collect(),
    ));
    table.push_series(Series::new("startup_overhead_s", startup.clone()));
    table.emit();

    // Recover the ln N coordination coefficient from measurements — the
    // only reading of "FHDSC = FHSSC = ln N" consistent with the sim.
    // Each Apriori level is one MR job paying its own coordination round,
    // so the expected coefficient is coordination_s × n_levels.
    let pts: Vec<(usize, f64)> = ns.iter().copied().zip(startup.iter().copied()).collect();
    let (a, b) = EtaModel::fit_log(&pts);
    let expected = 2.0 * report.profile.levels.len() as f64;
    println!(
        "startup(N) ≈ {a:.2} + {b:.2}·ln N  (expected coefficient {expected:.1} = 2.0 × {} level-jobs)",
        report.profile.levels.len()
    );
    assert!(
        (b - expected).abs() < 0.05,
        "fit must recover the ln N coordination coefficient {expected}, got {b}"
    );

    // η stays > 1 (FHDSC slower) — the fig-4-consistent reading.
    for (i, &n) in ns.iter().enumerate() {
        assert!(
            eta_meas[i] > 1.0,
            "n={n}: measured η={} must exceed the paper's literal 1.0",
            eta_meas[i]
        );
    }
    println!("shape checks passed: η>1 everywhere; ln N coefficient recovered");
}

//! Ablation A4: speculative execution under stragglers. One node of a
//! 4-node FHSSC cluster unexpectedly degrades after scheduling (thermal
//! throttle / noisy neighbour); we sweep the degradation factor and
//! compare simulated makespan with speculation on vs off.

use mr_apriori::mapreduce::{SimJobSpec, SimMapTask, Simulator};
use mr_apriori::prelude::*;

fn spec(n_maps: usize, n_nodes: usize, speculative: bool, surprise: f64) -> SimJobSpec {
    SimJobSpec {
        map_tasks: (0..n_maps)
            .map(|i| SimMapTask {
                bytes: 16_000_000,
                work: 8.0e6,
                replicas: vec![i % n_nodes, (i + 1) % n_nodes, (i + 2) % n_nodes],
                spilled: false,
            })
            .collect(),
        n_reducers: n_nodes,
        shuffle_bytes_per_map: 1_000_000,
        reduce_work: 2.0e6,
        speculative,
        surprise: (surprise > 1.0).then_some((3, surprise)),
    }
}

fn main() {
    println!("== Ablation A4: speculative execution vs stragglers ==\n");
    let sim = Simulator::new(ClusterConfig::fhssc(4));
    let factors = [1.0f64, 2.0, 4.0, 8.0, 16.0];

    let mut off = Vec::new();
    let mut on = Vec::new();
    let mut speculated = Vec::new();
    for &f in &factors {
        let r_off = sim.run(&spec(48, 4, false, f));
        let r_on = sim.run(&spec(48, 4, true, f));
        off.push(r_off.total_secs);
        on.push(r_on.total_secs);
        speculated.push(r_on.speculated as f64);
    }

    let mut table = BenchTable::new(
        "A4 — makespan (s) vs straggler slowdown on node 3 (4-node FHSSC)",
        "slowdown_factor",
        factors.to_vec(),
    );
    table.push_series(Series::new("speculation_off", off.clone()));
    table.push_series(Series::new("speculation_on", on.clone()));
    table.push_series(Series::new("tasks_speculated", speculated.clone()));
    table.emit();

    // No straggler -> speculation changes nothing.
    assert_eq!(off[0], on[0], "no-straggler case must be identical");
    // Heavy straggler -> speculation must win materially.
    let last = factors.len() - 1;
    assert!(
        on[last] < off[last] * 0.8,
        "speculation must cut the heavy-straggler makespan by >20%: {} vs {}",
        on[last],
        off[last]
    );
    assert!(speculated[last] > 0.0);
    // Speculation-off makespan grows with the degradation factor.
    assert!(off[last] > off[0] * 2.0, "straggler must dominate without mitigation");
    println!("shape checks passed: speculation absorbs stragglers");
}

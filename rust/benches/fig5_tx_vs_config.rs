//! Figure 5 reproduction: runtime vs transaction volume for the paper's
//! three deployments (standalone PC, pseudo-distributed, 3-node fully
//! distributed), including the ~12 000-transaction storage knee.
//!
//! The paper attributes the knee to the 80 GB/node disks filling up; we
//! scale the per-node capacity so the same knee appears at 12k
//! transactions (DESIGN.md §Substitutions), and also plot an uncapped
//! 3-node series to show the knee is exactly the storage effect.

use mr_apriori::coordinator;
use mr_apriori::prelude::*;

fn main() {
    println!("== Fig 5: Transactions vs Hadoop configuration ==\n");
    let volumes: Vec<usize> = (1..=12).map(|i| i * 2_000).collect();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };
    let split_tx = 500;
    let job = JobConfig::default();

    // Storage cap calibrated so the knee lands at 12k transactions: a node
    // holds exactly the bytes of a 12k-tx database (the "80 GB" analogue).
    let knee_db = QuestGenerator::new(QuestParams::t10_i4(12_000)).generate();
    let cap = knee_db.approx_bytes() as u64;

    let mut standalone = Vec::new();
    let mut pseudo = Vec::new();
    let mut fully = Vec::new();
    let mut fully_uncapped = Vec::new();

    for &v in &volumes {
        let db = QuestGenerator::new(QuestParams::t10_i4(v)).generate();
        // Profile once per volume (real mining on the standalone layout —
        // the profile captures candidate counts, which depend only on data).
        let report = MrApriori::new(ClusterConfig::standalone(), apriori.clone())
            .with_split_tx(split_tx)
            .mine(&db)
            .expect("profiling run");

        let sa = coordinator::simulate(
            &ClusterConfig::standalone().with_storage_per_node(cap),
            &report.profile,
            split_tx,
            &job,
        );
        let ps = coordinator::simulate(
            &ClusterConfig::pseudo_distributed().with_storage_per_node(cap),
            &report.profile,
            split_tx,
            &job,
        );
        let fd = coordinator::simulate(
            &ClusterConfig::fhssc(3).with_storage_per_node(cap),
            &report.profile,
            split_tx,
            &job,
        );
        let fd_roomy =
            coordinator::simulate(&ClusterConfig::fhssc(3), &report.profile, split_tx, &job);
        standalone.push(sa.total_secs);
        pseudo.push(ps.total_secs);
        fully.push(fd.total_secs);
        fully_uncapped.push(fd_roomy.total_secs);
    }

    let mut table = BenchTable::new(
        "Fig 5 — runtime vs transaction volume (capped storage, knee @ 12k)",
        "transactions",
        volumes.iter().map(|&v| v as f64).collect(),
    );
    table.push_series(Series::new("standalone", standalone.clone()));
    table.push_series(Series::new("pseudo_distributed", pseudo.clone()));
    table.push_series(Series::new("fully_distributed_3n", fully.clone()));
    table.push_series(Series::new("fully_3n_uncapped", fully_uncapped.clone()));
    table.emit();

    // Shape checks (the paper's qualitative claims).
    // 1. standalone wins at the smallest volume (framework overhead).
    assert!(
        standalone[0] < fully[0],
        "standalone must win at 2k tx: {} vs {}",
        standalone[0],
        fully[0]
    );
    // 2. distributed wins at the largest volume.
    let last = volumes.len() - 1;
    assert!(
        fully[last] < standalone[last],
        "3-node must win at 24k tx: {} vs {}",
        fully[last],
        standalone[last]
    );
    // 3. the knee: the per-transaction slope beyond 12k must be much
    //    steeper than before it for the capped standalone series.
    let idx12 = volumes.iter().position(|&v| v == 12_000).unwrap();
    let pre_slope = (standalone[idx12] - standalone[0])
        / (volumes[idx12] - volumes[0]) as f64;
    let post_slope =
        (standalone[last] - standalone[idx12]) / (volumes[last] - volumes[idx12]) as f64;
    assert!(
        post_slope > pre_slope * 1.5,
        "capped growth must accelerate past the knee: {post_slope} vs {pre_slope}"
    );
    // 4. ...and the gap to the uncapped cluster widens past the knee.
    assert!(
        fully[last] / fully_uncapped[last] > fully[idx12] / fully_uncapped[idx12],
        "the knee must come from the storage cap"
    );
    println!(
        "shape checks passed: crossover, knee at 12k (slope {pre_slope:.4} -> {post_slope:.4} s/tx), cap-driven"
    );
}

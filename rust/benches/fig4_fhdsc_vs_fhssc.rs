//! Figure 4 reproduction: FHDSC vs FHSSC processing time as cluster size
//! grows. Methodology (DESIGN.md §Experiment-index): mine the workload
//! once to capture its per-level cost profile, then replay the profile on
//! homogeneous (FHSSC) and differential (FHDSC) clusters of 2..16 nodes.
//!
//! Expected shape (paper fig 4): FHDSC is uniformly slower, with the gap
//! governed by the heterogeneity mix; both curves fall as N grows.

use mr_apriori::coordinator;
use mr_apriori::prelude::*;

fn main() {
    println!("== Fig 4: FHDSC vs FHSSC ==\n");
    let db = QuestGenerator::new(QuestParams::t10_i4(6_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };
    let report = MrApriori::new(ClusterConfig::fhssc(3), apriori)
        .with_split_tx(250)
        .mine(&db)
        .expect("profiling run");
    println!(
        "workload: {} tx, {} frequent itemsets, {} levels\n",
        db.len(),
        report.result.frequent.len(),
        report.profile.levels.len()
    );

    let ns = [2usize, 3, 4, 6, 8, 12, 16];
    let job = JobConfig::default();
    let mut fhssc = Vec::new();
    let mut fhdsc = Vec::new();
    let mut eta = Vec::new();
    let model = EtaModel::default();
    for &n in &ns {
        let hom = coordinator::simulate(&ClusterConfig::fhssc(n), &report.profile, 250, &job);
        let het = coordinator::simulate(&ClusterConfig::fhdsc(n), &report.profile, 250, &job);
        fhssc.push(hom.total_secs);
        fhdsc.push(het.total_secs);
        eta.push(het.total_secs / hom.total_secs);
    }

    let mut table = BenchTable::new(
        "Fig 4 — processing time vs cluster size (simulated testbed)",
        "nodes",
        ns.iter().map(|&n| n as f64).collect(),
    );
    table.push_series(Series::new("FHSSC_secs", fhssc.clone()));
    table.push_series(Series::new("FHDSC_secs", fhdsc.clone()));
    table.push_series(Series::new("eta_measured", eta.clone()));
    table.push_series(Series::new(
        "eta_model",
        ns.iter().map(|&n| model.eta_predicted(n)).collect(),
    ));
    table.emit();

    // Shape assertions — the reproduction claims of DESIGN.md.
    for (i, &n) in ns.iter().enumerate() {
        assert!(
            fhdsc[i] > fhssc[i],
            "n={n}: FHDSC must be slower (paper fig 4)"
        );
    }
    assert!(
        fhssc[ns.len() - 1] < fhssc[0],
        "FHSSC must speed up with more nodes"
    );
    println!("shape checks passed: FHDSC > FHSSC at every N; scaling helps");
}

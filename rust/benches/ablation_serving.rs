//! Ablation: online rule serving under snapshot hot-swap.
//!
//! A closed-loop load generator (4 client threads, bounded queue, 4
//! workers) drives the `serve/` stack through three phases over one
//! QUEST T10.I4 workload:
//!
//! * `frozen`    — steady-state load against the base snapshot;
//! * `refresh`   — the same load while a micro-batch refresh appends a
//!                 delta, re-mines the union database in the background
//!                 (pipelined driver) and hot-swaps the index;
//! * `post-swap` — steady-state load against the new generation.
//!
//! The differential assertions are the point: every served answer must
//! be byte-identical to the direct `generate_rules` path for the
//! generation it was served from — before the swap (vs the base mining
//! result), during it (each response attributed by generation, so a torn
//! or dropped read cannot hide), and after it (vs a re-mine of the union
//! database). QPS and p50/p95/p99 latency are reported per phase from
//! the server's own histogram.
//!
//! A second, **open-loop** section then injects requests on a
//! deterministic arrival schedule (request i is due exactly
//! i × interarrival after phase start — a fixed integer schedule, no
//! wall-clock randomness) instead of waiting for answers. Closed-loop
//! clients self-throttle, which hides queueing; open-loop injection
//! exposes the queueing delay and the admission-control knee at
//! saturation: a paced phase, a burst phase (every arrival due at t=0)
//! that overflows the bounded queue, and a burst phase with a queue
//! deadline showing deadline sheds counted apart from overflow sheds.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mr_apriori::prelude::*;
use mr_apriori::util::rng::Xoshiro256;

const MIN_CONFIDENCE: f64 = 0.5;
const TOP_K: usize = 5;
const CLIENTS: usize = 4;
const QUERIES: usize = 400;

fn check_phase(server: &RuleServer, baskets: &[Vec<u32>], rules: &[Rule], generation: u64) {
    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            scope.spawn(move || {
                for basket in baskets.iter().skip(c).step_by(CLIENTS) {
                    let resp = server.query(basket, TOP_K).expect("answer");
                    assert_eq!(resp.generation, generation, "basket {basket:?}");
                    assert_eq!(
                        resp.render(),
                        render_lines(&reference_recommend(rules, basket, TOP_K)),
                        "served != direct generate_rules for {basket:?}"
                    );
                }
            });
        }
    });
}

fn micros(d: std::time::Duration) -> f64 {
    d.as_secs_f64() * 1e6
}

/// One open-loop phase: `requests` arrivals on the deterministic
/// schedule `due_i = i × interarrival` (spin-paced; the schedule itself
/// is pure integer arithmetic), non-blocking admission, tickets drained
/// afterwards. Returns (answered, overflow sheds, deadline sheds, wall,
/// queueing-delay histogram).
fn open_loop_phase(
    cell: &Arc<SnapshotCell<RuleIndex>>,
    baskets: &[Vec<u32>],
    interarrival: Duration,
    requests: usize,
    deadline: Option<Duration>,
) -> (u64, u64, u64, f64, HistogramSnapshot) {
    let server = RuleServer::start(
        Arc::clone(cell),
        ServeOptions { workers: 1, queue_depth: 32, deadline, ..Default::default() },
    );
    let start = Instant::now();
    let mut tickets = Vec::with_capacity(requests);
    let mut overflow = 0u64;
    for i in 0..requests {
        let due = interarrival * i as u32;
        while start.elapsed() < due {
            std::hint::spin_loop();
        }
        match server.submit(&baskets[i % baskets.len()], TOP_K) {
            Ok(ticket) => tickets.push(ticket),
            Err(ServeError::QueueFull) => overflow += 1,
            Err(e) => panic!("open-loop submit failed: {e}"),
        }
    }
    let mut answered = 0u64;
    let mut deadline_shed = 0u64;
    for ticket in tickets {
        match ticket.wait() {
            Ok(_) => answered += 1,
            Err(ServeError::DeadlineExceeded) => deadline_shed += 1,
            Err(e) => panic!("open-loop wait failed: {e}"),
        }
    }
    let wall = start.elapsed().as_secs_f64();
    let stats = server.shutdown();
    // conservation: every injected request is answered or shed, exactly
    // once, and the server's counters agree with the client's view
    assert_eq!(stats.served, answered);
    assert_eq!(stats.rejected, overflow);
    assert_eq!(stats.deadline_shed, deadline_shed);
    assert_eq!(answered + overflow + deadline_shed, requests as u64);
    assert_eq!(stats.latency.count(), answered, "sheds must leave no samples");
    (answered, overflow, deadline_shed, wall, stats.latency)
}

fn main() {
    println!("== Ablation: online serving with snapshot hot-swap ==\n");
    let mut db = QuestGenerator::new(QuestParams::t10_i4(4_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };
    let cluster = ClusterConfig::fhssc(3);
    let job = JobConfig { n_reducers: 3, ..Default::default() };

    let base_driver = MrApriori::new(cluster.clone(), apriori.clone())
        .with_job(job.clone())
        .with_split_tx(500);
    let report0 = base_driver.mine(&db).expect("base mine");
    let rules0 = generate_rules(&report0.result, MIN_CONFIDENCE);
    println!(
        "base generation: {} tx, {} frequent itemsets, {} rules at conf >= {}",
        db.len(),
        report0.result.frequent.len(),
        rules0.len(),
        MIN_CONFIDENCE,
    );

    let singles: Vec<u32> = report0.result.level(1).map(|(is, _)| is[0]).collect();
    let baskets = synth_baskets(&singles, QUERIES, 0xBA5E);

    let index0 = RuleIndex::build(&report0.result, MIN_CONFIDENCE);
    let cell = Arc::new(SnapshotCell::new(Arc::new(index0)));
    let server = RuleServer::start(
        Arc::clone(&cell),
        ServeOptions { workers: 4, queue_depth: 256, ..Default::default() },
    );

    // ---- phase 0 (frozen): differential vs the base generation ----
    let t_a = Instant::now();
    check_phase(&server, &baskets, &rules0, 0);
    let wall_a = t_a.elapsed().as_secs_f64();
    let snap_a = server.stats().latency;

    // ---- phase 1 (refresh): same load, concurrent re-mine + hot-swap ----
    let delta = synth_delta(800, db.n_items, 0xD117A);
    let refresher = Refresher::new(
        MrApriori::new(cluster.clone(), apriori.clone())
            .with_job(job.clone())
            .with_pipeline(PipelineConfig::pipelined())
            .with_split_tx(500),
        MIN_CONFIDENCE,
    );
    let refresh_done = AtomicBool::new(false);
    let t_b = Instant::now();
    let (refresh_out, client_out) = std::thread::scope(|scope| {
        let refresh_handle = scope.spawn(|| {
            // Drop guard: flag the clients even if the refresh unwinds,
            // so a failed refresh fails the bench loudly instead of
            // leaving the client loops spinning forever.
            struct Done<'a>(&'a AtomicBool);
            impl Drop for Done<'_> {
                fn drop(&mut self) {
                    self.0.store(true, Ordering::Release);
                }
            }
            let _done = Done(&refresh_done);
            refresher.refresh_once(&mut db, delta, &cell).expect("refresh cycle")
        });
        let clients: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let (server, baskets, rules0) = (&server, &baskets, &rules0);
                let refresh_done = &refresh_done;
                scope.spawn(move || {
                    let mut answered = 0u64;
                    let mut deferred: Vec<(usize, String)> = Vec::new();
                    // at least one full pass, then loop until the swap lands
                    loop {
                        for (i, basket) in baskets.iter().enumerate().skip(c).step_by(CLIENTS) {
                            let resp = server.query(basket, TOP_K).expect("phase-1 answer");
                            answered += 1;
                            match resp.generation {
                                // pre-swap answers check against the base rules
                                0 => assert_eq!(
                                    resp.render(),
                                    render_lines(&reference_recommend(rules0, basket, TOP_K)),
                                    "pre-swap served != direct for {basket:?}"
                                ),
                                // post-swap answers are checked once the
                                // refresh hands back the union mining result
                                1 => deferred.push((i, resp.render())),
                                g => panic!("impossible generation {g}"),
                            }
                        }
                        if refresh_done.load(Ordering::Acquire) {
                            break;
                        }
                    }
                    (answered, deferred)
                })
            })
            .collect();
        let client_out: Vec<_> = clients.into_iter().map(|h| h.join().unwrap()).collect();
        (refresh_handle.join().unwrap(), client_out)
    });
    let wall_b = t_b.elapsed().as_secs_f64();
    let snap_b = server.stats().latency;

    let (report1, refresh_stats) = refresh_out;
    assert_eq!(refresh_stats.generation, 1);
    assert_eq!(cell.generation(), 1);
    let rules1 = generate_rules(&report1.result, MIN_CONFIDENCE);
    assert_eq!(refresh_stats.n_rules, rules1.len());

    // resolve the deferred (post-swap) phase-1 answers differentially
    let answered_b: u64 = client_out.iter().map(|(n, _)| n).sum();
    let mut deferred_checked = 0usize;
    for (i, rendered) in client_out.iter().flat_map(|(_, d)| d) {
        assert_eq!(
            rendered,
            &render_lines(&reference_recommend(&rules1, &baskets[*i], TOP_K)),
            "post-swap served != direct for basket {i}"
        );
        deferred_checked += 1;
    }
    println!(
        "refresh gen 1: +{} tx -> {} tx, {} rules (mine {:.3}s, build {:.3}s); \
         {} in-flight answers attributed to it and verified",
        refresh_stats.delta_tx,
        refresh_stats.total_tx,
        refresh_stats.n_rules,
        refresh_stats.mine_secs,
        refresh_stats.build_secs,
        deferred_checked,
    );

    // ---- phase 2 (post-swap): differential vs the union generation ----
    let t_c = Instant::now();
    check_phase(&server, &baskets, &rules1, 1);
    let wall_c = t_c.elapsed().as_secs_f64();
    let snap_c = server.stats().latency;

    let stats = server.shutdown();
    // every query produced exactly one recorded answer: nothing dropped
    let expected = 2 * QUERIES as u64 + answered_b;
    assert_eq!(stats.served, expected, "dropped or duplicated answers");
    assert_eq!(stats.rejected, 0, "closed-loop load must never be shed");

    let phases = [
        ("frozen", QUERIES as u64, wall_a, snap_a.clone()),
        ("refresh", answered_b, wall_b, snap_b.diff(&snap_a)),
        ("post-swap", QUERIES as u64, wall_c, snap_c.diff(&snap_b)),
    ];
    let mut table = BenchTable::new(
        "Ablation: serving QPS + tails, frozen vs concurrent refresh (T10.I4 4k tx)",
        "phase",
        (0..phases.len()).map(|i| i as f64).collect(),
    );
    let series: [(&str, Vec<f64>); 4] = [
        ("qps", phases.iter().map(|p| p.1 as f64 / p.2.max(1e-9)).collect()),
        ("p50_us", phases.iter().map(|p| micros(p.3.quantile(0.50))).collect()),
        ("p95_us", phases.iter().map(|p| micros(p.3.quantile(0.95))).collect()),
        ("p99_us", phases.iter().map(|p| micros(p.3.quantile(0.99))).collect()),
    ];
    for (name, values) in series {
        table.push_series(Series::new(name, values));
    }
    table.emit();
    for (i, p) in phases.iter().enumerate() {
        println!("phase {i} = {} ({} answers)", p.0, p.1);
    }
    println!(
        "\nall {} answers byte-identical to direct generate_rules for their \
         generation; snapshot swap dropped nothing",
        stats.served,
    );

    // ---- open-loop section: arrival-rate injection vs saturation ----
    println!("\n== Open-loop: deterministic arrival schedule vs saturation ==\n");
    const OL_REQUESTS: usize = 400;
    // Wide baskets (many frequent singles each) make one query cost an
    // order of magnitude more than one injection, so the burst phase
    // saturates the single worker on any machine.
    let mut ol_rng = Xoshiro256::seed_from_u64(0x09E7);
    let heavy = 14.min(singles.len());
    let ol_baskets: Vec<Vec<u32>> = (0..64)
        .map(|_| {
            ol_rng
                .sample_distinct(singles.len(), heavy)
                .into_iter()
                .map(|i| singles[i])
                .collect()
        })
        .collect();
    // paced: 1 kQPS offered against one worker — far below service rate,
    // so queueing delay stays near pure service time
    let (ans_p, ovf_p, _, wall_p, snap_p) =
        open_loop_phase(&cell, &ol_baskets, Duration::from_micros(1000), OL_REQUESTS, None);
    // burst: every arrival due at t = 0 (interarrival 0) — offered rate
    // is bounded only by the injector, the 32-deep queue must overflow
    let (ans_b, ovf_b, _, wall_b, snap_b) =
        open_loop_phase(&cell, &ol_baskets, Duration::ZERO, OL_REQUESTS, None);
    assert!(
        ovf_b > 0,
        "burst injection against a 32-deep queue with one worker must shed"
    );
    // burst + zero queue deadline: everything the queue admits ages out
    // before the worker computes it — deadline sheds are counted apart
    // from the overflow sheds and leave no latency samples
    let (ans_d, ovf_d, dl_d, wall_d, snap_d) =
        open_loop_phase(&cell, &ol_baskets, Duration::ZERO, OL_REQUESTS, Some(Duration::ZERO));
    assert_eq!(ans_d, 0, "a zero deadline must shed every admitted request");
    assert!(dl_d > 0);

    let ol_phases = [
        ("paced-1k", ans_p, ovf_p, 0, wall_p, snap_p),
        ("burst", ans_b, ovf_b, 0, wall_b, snap_b),
        ("burst+deadline", ans_d, ovf_d, dl_d, wall_d, snap_d),
    ];
    let mut ol_table = BenchTable::new(
        "Open-loop: queueing delay + sheds vs offered load (1 worker, queue 32)",
        "phase",
        (0..ol_phases.len()).map(|i| i as f64).collect(),
    );
    let ol_series: [(&str, Vec<f64>); 5] = [
        (
            "achieved_qps",
            ol_phases.iter().map(|p| p.1 as f64 / p.4.max(1e-9)).collect(),
        ),
        ("overflow_shed", ol_phases.iter().map(|p| p.2 as f64).collect()),
        ("deadline_shed", ol_phases.iter().map(|p| p.3 as f64).collect()),
        (
            "queue_p50_us",
            ol_phases.iter().map(|p| micros(p.5.quantile(0.50))).collect(),
        ),
        (
            "queue_p99_us",
            ol_phases.iter().map(|p| micros(p.5.quantile(0.99))).collect(),
        ),
    ];
    for (name, values) in ol_series {
        ol_table.push_series(Series::new(name, values));
    }
    ol_table.emit();
    for (i, p) in ol_phases.iter().enumerate() {
        println!(
            "phase {i} = {}: {} answered, {} overflow-shed, {} deadline-shed",
            p.0, p.1, p.2, p.3
        );
    }
    println!(
        "\nopen-loop injection exposes what closed-loop hides: the burst phase \
         queues to the admission knee (overflow sheds) and its p99 queueing \
         delay dwarfs the paced phase's"
    );
}

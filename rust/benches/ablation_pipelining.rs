//! Ablation: pipelined multi-level job scheduling + batched shared-scan
//! counting vs the paper's synchronous one-job-per-level driver.
//!
//! Three schedules mine the same QUEST workload end-to-end on the real
//! multi-threaded MapReduce engine:
//!
//! * `synchronous`     — run job k to completion, then plan job k+1;
//! * `pipelined`       — job k+1's map wave overlaps job k's reduce wave
//!                       (optimistic look-ahead candidates, exactness
//!                       restored at resolve time);
//! * `pipelined+batch` — additionally counts two adjacent levels per job
//!                       through the engines' shared-scan `count_batch`,
//!                       halving the number of jobs and dataset passes.
//!
//! The bench asserts all three emit byte-identical frequent itemsets (the
//! differential proof) and reports real wall-clock plus the simulated
//! cluster makespan, where Hadoop's per-job setup latency — the overhead
//! the pipeline removes — is modelled explicitly.

use std::time::Instant;

use mr_apriori::coordinator;
use mr_apriori::prelude::*;

fn main() {
    println!("== Ablation: pipelined vs synchronous level scheduling ==\n");
    let db = QuestGenerator::new(QuestParams::t10_i4(8_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 4 };
    let cluster = ClusterConfig::fhssc(3);
    let job = JobConfig { n_reducers: 3, ..Default::default() };

    let modes: [(&str, Option<PipelineConfig>); 3] = [
        ("synchronous", None),
        (
            "pipelined",
            Some(PipelineConfig {
                enabled: true,
                batch_levels: 1,
                ..Default::default()
            }),
        ),
        (
            "pipelined+batch2",
            Some(PipelineConfig {
                enabled: true,
                batch_levels: 2,
                ..Default::default()
            }),
        ),
    ];

    let mut names = Vec::new();
    let mut walls = Vec::new();
    let mut n_jobs = Vec::new();
    let mut reference: Option<Vec<(Itemset, u64)>> = None;
    let mut base_profile = None;

    for (name, pipeline) in modes {
        let mut driver = MrApriori::new(cluster.clone(), apriori.clone())
            .with_job(job.clone())
            .with_split_tx(250);
        if let Some(p) = pipeline {
            driver = driver.with_pipeline(p);
        }
        let t0 = Instant::now();
        let report = driver.mine(&db).expect("mining run");
        let wall = t0.elapsed().as_secs_f64();

        // Differential proof: every schedule mines identical itemsets.
        match &reference {
            None => reference = Some(report.result.frequent.clone()),
            Some(base) => assert_eq!(
                &report.result.frequent, base,
                "{name} diverged from the synchronous baseline"
            ),
        }
        base_profile.get_or_insert(report.profile);

        println!("{name:>18}: wall {wall:.3}s | {} MR jobs", report.jobs.len());
        names.push(name);
        walls.push(wall);
        n_jobs.push(report.jobs.len() as f64);
    }

    println!(
        "\nfrequent itemsets: {} (identical across schedules)\n",
        reference.as_ref().map(|r| r.len()).unwrap_or(0)
    );

    let mut table = BenchTable::new(
        "Ablation: level-scheduling pipeline (QUEST T10.I4, 8k tx, fhssc/3)",
        "schedule",
        (0..names.len()).map(|i| i as f64).collect(),
    );
    table.push_series(Series::new("wall_secs", walls.clone()));
    table.push_series(Series::new("mr_jobs", n_jobs));
    table.emit();
    for (i, name) in names.iter().enumerate() {
        println!("schedule {i} = {name}");
    }

    let base_wall = walls[0];
    for i in 1..names.len() {
        println!(
            "{:>18}: real wall speedup {:.2}x",
            names[i],
            base_wall / walls[i].max(1e-9),
        );
    }

    // Schedule-model comparison on the simulated Hadoop cluster, where
    // per-job setup latency is explicit. ONE workload profile (the sync
    // run's) replayed under both sequencers — comparing profiles captured
    // from different runs would conflate speculative counting work with
    // scheduling gains. The batch2 variant's extra win (half the jobs and
    // dataset passes) is visible in the wall/mr_jobs columns above, not
    // here: the per-level replay models the same overlap for both
    // pipelined variants.
    let profile = base_profile.expect("at least one run");
    let sim_sync = coordinator::simulate(&cluster, &profile, 250, &job);
    let sim_piped = coordinator::simulate_pipelined(&cluster, &profile, 250, &job);
    println!(
        "\nsimulated 3-node makespan: synchronous {:.1}s vs pipelined {:.1}s ({:.2}x)",
        sim_sync.total_secs,
        sim_piped.total_secs,
        sim_sync.total_secs / sim_piped.total_secs.max(1e-9),
    );
    assert!(
        sim_piped.total_secs < sim_sync.total_secs,
        "pipelined schedule must beat the synchronous makespan"
    );
}

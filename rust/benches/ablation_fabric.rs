//! Ablation: the sharded serving fabric vs the single-index backend.
//!
//! The fabric's claim is scale-out *without* answer drift: splitting the
//! rule index over S shards x R replicas keeps every basket answer
//! byte-identical to the one `RuleIndex` while adding failover and
//! hedged tails. This bench measures, on one mined generation:
//!
//! * **baseline**: single-index closed-loop QPS and wall-clock p99;
//! * **shards x replicas sweep**: routed QPS plus the *simulated* wire
//!   p50/p99 (the router's network model), every answer asserted
//!   byte-identical to the baseline;
//! * **hedging on/off**: the p95-derived hedge can only improve the
//!   simulated tail (asserted), reported as a >= 1 improvement ratio;
//! * **kill-one-replica phase**: a node dies mid-run — availability must
//!   stay 100% (every query answered, byte-identical), and the refresher
//!   still publishes the next generation around the dead replicas.
//!
//! Results land in `BENCH_fabric.json` (directory override:
//! `BENCH_OUT_DIR`), gated by `tools/bench_gate.py`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use mr_apriori::prelude::*;
use mr_apriori::util::json::Json;
use mr_apriori::util::tempdir::TempDir;

const MIN_CONF: f64 = 0.5;
const QUERIES: usize = 1_000;
const TOP_K: usize = 5;
const HEDGE_MS: u64 = 5;

fn driver(apriori: &AprioriConfig) -> MrApriori {
    MrApriori::new(ClusterConfig::fhssc(4), apriori.clone())
        .with_job(JobConfig { n_reducers: 3, ..Default::default() })
        .with_split_tx(500)
}

fn router_for(
    result: &MiningResult,
    cluster: &ClusterConfig,
    shards: usize,
    replicas: usize,
) -> QueryRouter {
    let cut = ShardedRuleIndex::build(result, MIN_CONF, shards);
    let bytes: Vec<u64> = cut.shard_rule_counts().iter().map(|&n| 16 + 56 * n).collect();
    let placement = FabricPlacement::place(cluster, replicas, &bytes).expect("placement");
    QueryRouter::new(
        Arc::new(SnapshotCell::new(Arc::new(cut))),
        placement,
        cluster,
        HEDGE_MS,
    )
}

/// Route every basket, assert byte-identity against `want`, and return
/// (closed-loop QPS, simulated p50 us, simulated p99 us).
fn run_arm(router: &QueryRouter, baskets: &[Vec<u32>], want: &[String]) -> (f64, f64, f64) {
    let sim = LatencyHistogram::new();
    let t0 = Instant::now();
    for (basket, want) in baskets.iter().zip(want) {
        let routed = router.route(basket, TOP_K).expect("all replicas up");
        assert_eq!(
            &render_lines(&routed.recommendations),
            want,
            "fabric answer diverged from the single index for {basket:?}"
        );
        sim.record(Duration::from_secs_f64(routed.sim_latency_secs));
    }
    let wall = t0.elapsed().as_secs_f64();
    let (p50, _, p99) = sim.snapshot().p50_p95_p99();
    (
        baskets.len() as f64 / wall.max(1e-9),
        p50.as_secs_f64() * 1e6,
        p99.as_secs_f64() * 1e6,
    )
}

fn main() {
    println!("== Ablation: sharded serving fabric vs single index ==\n");
    let db = QuestGenerator::new(QuestParams::t10_i4(4_000)).generate();
    let apriori = AprioriConfig { min_support: 0.02, max_k: 3 };
    let cluster = ClusterConfig::fhssc(4);
    let result = driver(&apriori).mine(&db).expect("mine").result;
    let index = RuleIndex::build(&result, MIN_CONF);
    let singles: Vec<u32> = result.level(1).map(|(is, _)| is[0]).collect();
    assert!(!singles.is_empty(), "nothing frequent at this support");
    let baskets = synth_baskets(&singles, QUERIES, 0xFAB_BE7C);

    // -- baseline: the single-index backend --
    let wall_hist = LatencyHistogram::new();
    let t0 = Instant::now();
    let want: Vec<String> = baskets
        .iter()
        .map(|b| {
            let t = Instant::now();
            let lines = render_lines(&index.recommend(b, TOP_K));
            wall_hist.record(t.elapsed());
            lines
        })
        .collect();
    let base_wall = t0.elapsed().as_secs_f64();
    let base_qps = QUERIES as f64 / base_wall.max(1e-9);
    let (_, _, base_p99) = wall_hist.snapshot().p50_p95_p99();
    println!(
        "single index: {} rules, {base_qps:.0} QPS closed-loop, wall p99 {base_p99:?}",
        index.n_rules()
    );

    // -- shards x replicas sweep --
    println!("\nshards | replicas | QPS     | sim p50 | sim p99 | hedges(won)");
    let mut sweep_rows = Vec::new();
    for &shards in &[1usize, 2, 4, 8] {
        for &replicas in &[2usize, 3] {
            let router = router_for(&result, &cluster, shards, replicas);
            let (qps, p50_us, p99_us) = run_arm(&router, &baskets, &want);
            let rs = router.stats();
            println!(
                "{shards:>6} | {replicas:>8} | {qps:>7.0} | {p50_us:>6.1}u | {p99_us:>6.1}u | {}({})",
                rs.hedges_fired, rs.hedge_wins
            );
            sweep_rows.push(Json::obj(vec![
                ("shards", Json::num(shards as f64)),
                ("replicas", Json::num(replicas as f64)),
                ("qps", Json::num(qps)),
                ("sim_p50_us", Json::num(p50_us)),
                ("sim_p99_us", Json::num(p99_us)),
                ("hedges_fired", Json::num(rs.hedges_fired as f64)),
                ("hedge_wins", Json::num(rs.hedge_wins as f64)),
                ("byte_identical", Json::Bool(true)), // run_arm asserted it
            ]));
        }
    }

    // -- hedging on/off (4x2): the hedge can only improve the tail --
    let hedged = router_for(&result, &cluster, 4, 2);
    let (_, _, p99_on) = run_arm(&hedged, &baskets, &want);
    let unhedged = router_for(&result, &cluster, 4, 2).with_hedging(false);
    let (_, _, p99_off) = run_arm(&unhedged, &baskets, &want);
    assert!(
        p99_on <= p99_off + 1e-9,
        "hedging worsened the simulated p99: {p99_on:.1}us vs {p99_off:.1}us"
    );
    let hedge_improvement = p99_off / p99_on.max(1e-9);
    println!(
        "\nhedging (4x2): sim p99 {p99_on:.1}us on vs {p99_off:.1}us off \
         ({hedge_improvement:.3}x)"
    );

    // -- kill-one-replica phase (4x2) --
    let tmp = TempDir::new("fabric_bench");
    let router = router_for(&result, &cluster, 4, 2);
    let store = FabricStore::open(tmp.path(), 4, 2).expect("open fabric store");
    store.publish(&router.cut().load(), 0).expect("publish gen 0");
    let victim = router.placement().replicas_of(0)[0];
    let mut answered = 0usize;
    for (i, (basket, want)) in baskets.iter().zip(&want).enumerate() {
        if i == QUERIES / 2 {
            router.set_node_down(victim);
        }
        let routed = router.route(basket, TOP_K).expect("failover keeps the fabric up");
        assert_eq!(&render_lines(&routed.recommendations), want);
        answered += 1;
    }
    let availability = answered as f64 / QUERIES as f64;
    assert_eq!(answered, QUERIES, "availability must stay 100% with one node down");
    let kill_stats = router.stats();
    assert!(kill_stats.failovers > 0, "the dead primary was never failed over");

    // the refresher still publishes the next generation around the dead
    // node: mine the grown database, two-phase publish to the survivors
    let mut union = db.clone();
    union.append(synth_delta(200, db.n_items, 0xFAB_DE17A));
    let next_result = driver(&apriori).mine(&union).expect("re-mine").result;
    let next = Arc::new(ShardedRuleIndex::build(&next_result, MIN_CONF, 4));
    let up = |s: usize, r: usize| !router.is_node_down(router.placement().replicas_of(s)[r]);
    let manifest = store.publish_partial(&next, 1, &up).expect("publish gen 1");
    assert_eq!(manifest.generation, 1);
    assert_eq!(router.cut().store(Arc::clone(&next)), 1);
    let (reloaded, _) = FabricStore::open(tmp.path(), 4, 2)
        .expect("reopen")
        .load_cut()
        .expect("gen 1 committed");
    assert_eq!(reloaded.generation, 1);
    let next_index = RuleIndex::build(&next_result, MIN_CONF);
    let routed = router.route(&baskets[0], TOP_K).expect("serving gen 1");
    assert_eq!(routed.generation, 1);
    assert_eq!(
        render_lines(&routed.recommendations),
        render_lines(&next_index.recommend(&baskets[0], TOP_K)),
    );
    println!(
        "kill phase (4x2): {answered}/{QUERIES} answered with node {victim} down \
         ({} failovers); generation 1 published to the survivors and served",
        kill_stats.failovers
    );

    let doc = Json::obj(vec![
        (
            "baseline_single_index",
            Json::obj(vec![
                ("qps", Json::num(base_qps)),
                ("wall_p99_us", Json::num(base_p99.as_secs_f64() * 1e6)),
            ]),
        ),
        ("sweep", Json::Arr(sweep_rows)),
        (
            "hedging",
            Json::obj(vec![
                ("sim_p99_on_us", Json::num(p99_on)),
                ("sim_p99_off_us", Json::num(p99_off)),
                ("improvement", Json::num(hedge_improvement)),
            ]),
        ),
        (
            "kill_phase",
            Json::obj(vec![
                ("availability", Json::num(availability)),
                ("failovers", Json::num(kill_stats.failovers as f64)),
                ("published_next_generation", Json::num(1.0)),
                ("byte_identical", Json::Bool(true)),
            ]),
        ),
    ]);
    let out_dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out_dir).join("BENCH_fabric.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_fabric.json");
    println!("\nwrote {}", path.display());
}

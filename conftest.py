"""Pytest bootstrap: make `python/` importable so the suite runs from the
repo root (`pytest python/tests/`) as well as from `python/`."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))

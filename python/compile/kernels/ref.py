"""Pure-jnp oracle for the support-count kernel.

This is the correctness contract for L1: ``support_count(...)`` must match
``support_count_ref(...)`` bit-exactly (both are integer-valued f32).
Also AOT-lowered as a standalone artifact so the rust runtime can
differential-test the two compiled modules against each other.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def support_count_ref(tx, mask, cand, sizes):
    """Reference containment count.

    Same signature/shapes as ``support_count.support_count``:
      tx (T, I), mask (T, 1), cand (C, I), sizes (1, C) → counts (1, C).
    """
    overlap = jnp.dot(tx, cand.T, preferred_element_type=jnp.float32)  # (T, C)
    hit = (overlap == sizes).astype(jnp.float32) * mask  # (T, C)
    return jnp.sum(hit, axis=0, keepdims=True)  # (1, C)


def support_count_py(transactions, candidates):
    """Slow pure-python oracle over set representations (ground truth for
    both the jnp path and the bitmap encoding itself)."""
    counts = []
    for cand in candidates:
        cs = set(cand)
        counts.append(sum(1 for t in transactions if cs.issubset(t)))
    return counts

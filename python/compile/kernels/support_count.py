"""L1 — Pallas support-count kernel.

The Apriori hot-spot is candidate support counting: for every candidate
itemset c and every transaction t, decide whether c ⊆ t and accumulate the
per-candidate containment count. With transactions and candidates encoded as
{0,1} bitmap matrices over a dense item dictionary, containment becomes an
integer matmul:

    contains(t, c)  ⇔  dot(T[t, :], C[c, :]) == |c|

which is the canonical MXU (systolic array) workload. This is the TPU
re-think of the paper's Hadoop map task (DESIGN.md §Hardware-Adaptation):
the HBM→VMEM transaction stream plays the role of the HDFS split stream,
expressed with a BlockSpec grid instead of map-slot scheduling.

Tiling: the candidate matrix (C×I) and the per-candidate size row stay
VMEM-resident across the whole sweep; transactions stream through in
(TILE_T × I) blocks; the (1×C) accumulator lives in the output ref and is
accumulated across grid steps (zeroed at step 0).

All tensors are 2-D and f32: CPU-PJRT (interpret=True) executes f32
natively, and counts are exact in f32 as long as I < 2^24. On a real TPU
the matmul operands would be bf16 with an f32 accumulator — same layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile height for the transaction stream. 256×256 f32 = 256 KiB per
# operand block — two such blocks double-buffered plus a ≤512×256 resident
# candidate matrix stay well under the ~16 MiB VMEM budget (DESIGN.md §Perf).
TILE_T = 256


def _support_count_kernel(sizes_ref, tx_ref, mask_ref, cand_ref, o_ref):
    """One grid step: accumulate containment counts for one transaction tile.

    Refs (shapes per block):
      sizes_ref: (1, C)  f32 — |c| for each candidate (VMEM-resident)
      tx_ref:    (TILE_T, I) f32 — transaction bitmap tile (streamed)
      mask_ref:  (TILE_T, 1) f32 — 1.0 for live rows, 0.0 for padding
      cand_ref:  (C, I)  f32 — candidate bitmap (VMEM-resident)
      o_ref:     (1, C)  f32 — per-candidate counts (accumulated)
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (TILE_T, I) @ (I, C) -> (TILE_T, C) — the MXU matmul.
    overlap = jnp.dot(
        tx_ref[...], cand_ref[...].T, preferred_element_type=jnp.float32
    )
    # Containment: overlap equals the candidate's cardinality.
    hit = (overlap == sizes_ref[...]).astype(jnp.float32)
    # Mask out padding rows, then reduce over the tile.
    hit = hit * mask_ref[...]
    o_ref[...] += jnp.sum(hit, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile_t",))
def support_count(tx, mask, cand, sizes, *, tile_t: int = TILE_T):
    """Count, per candidate, the number of (unmasked) transactions containing it.

    Args:
      tx:    (T, I) f32 {0,1} transaction bitmap; T must be a multiple of
             ``tile_t`` (the caller pads and masks the remainder).
      mask:  (T, 1) f32 {0,1} row-liveness mask.
      cand:  (C, I) f32 {0,1} candidate bitmap.
      sizes: (1, C) f32 — cardinality |c| of each candidate row.

    Returns:
      (1, C) f32 — exact integer-valued support counts.
    """
    t, i = tx.shape
    c, i2 = cand.shape
    if i != i2:
        raise ValueError(f"item-width mismatch: tx has {i}, cand has {i2}")
    if t % tile_t != 0:
        raise ValueError(f"T={t} not a multiple of tile_t={tile_t}")
    grid = (t // tile_t,)
    return pl.pallas_call(
        _support_count_kernel,
        grid=grid,
        in_specs=[
            # sizes: whole row resident every step.
            pl.BlockSpec((1, c), lambda s: (0, 0)),
            # tx: stream tile s.
            pl.BlockSpec((tile_t, i), lambda s: (s, 0)),
            # mask: stream tile s.
            pl.BlockSpec((tile_t, 1), lambda s: (s, 0)),
            # cand: whole matrix resident every step.
            pl.BlockSpec((c, i), lambda s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, c), jnp.float32),
        # interpret=True: CPU-PJRT cannot execute Mosaic custom-calls; the
        # interpret path lowers to plain HLO the rust runtime can run.
        interpret=True,
    )(sizes, tx, mask, cand)

"""L2 — the jax compute graph the rust coordinator executes.

The "model" for this paper is the map-task compute: given one HDFS-split's
worth of transactions (bitmap-encoded by the rust side) and the current
level's candidate set, produce per-candidate support counts. The graph is
a thin, fully-fused wrapper over the L1 Pallas kernel — all batching over
splits, levels and nodes lives in the rust L3 coordinator, which calls one
compiled executable per (T, I, C) tile shape.

Two graph variants are exported:
  * ``count_split``      — the Pallas-kernel path (the product).
  * ``count_split_ref``  — the pure-jnp path (differential oracle, also
                           used for L1-vs-L2 perf comparison in §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.support_count import support_count
from .kernels.ref import support_count_ref


def count_split(tx, mask, cand, sizes):
    """Support counts for one transaction block (Pallas path).

    Shapes: tx (T, I), mask (T, 1), cand (C, I), sizes (1, C) → (1, C).
    Returned as a 1-tuple: the AOT bridge lowers with return_tuple=True and
    the rust side unwraps with to_tuple1 (see /opt/xla-example/README.md).
    """
    return (support_count(tx, mask, cand, sizes),)


def count_split_ref(tx, mask, cand, sizes):
    """Same computation, pure-jnp (no pallas_call) — the oracle module."""
    return (support_count_ref(tx, mask, cand, sizes),)


def example_args(t: int, i: int, c: int):
    """ShapeDtypeStructs for AOT lowering of either variant."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((t, i), f32),  # tx
        jax.ShapeDtypeStruct((t, 1), f32),  # mask
        jax.ShapeDtypeStruct((c, i), f32),  # cand
        jax.ShapeDtypeStruct((1, c), f32),  # sizes
    )

"""AOT bridge: lower the L2 graphs to HLO **text** artifacts.

Run once by ``make artifacts``; python never runs on the request path.

Interchange format is HLO text, NOT ``lowered.compile()``/``.serialize()``:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py there).

Emits one module per (variant, tile-shape) plus ``manifest.json`` which the
rust runtime (rust/src/runtime/artifacts.rs) uses to pick executables.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (t, i, c) tile shapes the rust TensorEngine can pick from. Keep this list
# in sync with nothing: rust discovers shapes from manifest.json at startup.
#   small  — unit tests / tiny splits
#   medium — default split shape for the fig5 workloads
#   large  — wide candidate levels (k=2 explosion)
VARIANTS = [
    ("small", 256, 64, 64),
    ("medium", 1024, 256, 256),
    ("large", 2048, 256, 512),
]

# The pallas module is the product; the ref module (pure jnp) ships for
# small/medium so the rust side can differential-test compiled artifacts.
GRAPHS = {
    "count_split": (model.count_split, ["small", "medium", "large"]),
    "count_split_ref": (model.count_split_ref, ["small", "medium"]),
}


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    modules = []
    for graph_name, (fn, variant_names) in GRAPHS.items():
        for vname in variant_names:
            _, t, i, c = next(v for v in VARIANTS if v[0] == vname)
            lowered = jax.jit(fn).lower(*model.example_args(t, i, c))
            text = to_hlo_text(lowered)
            fname = f"{graph_name}_{vname}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            modules.append(
                {
                    "graph": graph_name,
                    "variant": vname,
                    "path": fname,
                    "t": t,
                    "i": i,
                    "c": c,
                    "sha256": hashlib.sha256(text.encode()).hexdigest(),
                    "bytes": len(text),
                }
            )
            print(f"  wrote {fname}  (t={t} i={i} c={c}, {len(text)} chars)")
    manifest = {"format": 1, "modules": modules}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(modules)} modules)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()

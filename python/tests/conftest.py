"""The AOT/kernel tests need the optional jax (+hypothesis) toolchain.

Skip the whole directory cleanly when it is absent so the rust tier-1 CI
job (and a bare `pytest`) stays hermetic; the dedicated `python-aot` CI
job installs jax and runs these for real.
"""

import pytest

pytest.importorskip("jax", reason="jax not installed; AOT tests are optional")

"""L1 correctness: Pallas support-count kernel vs the pure-jnp oracle vs a
pure-python set oracle. This is the CORE correctness signal for the compiled
hot path — exact equality is required (counts are integer-valued f32)."""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; kernel tests are optional")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels.ref import support_count_ref, support_count_py
from compile.kernels.support_count import support_count


def encode_bitmaps(transactions, candidates, n_items, t_pad, rng=None):
    """Set-of-ints → padded f32 bitmap matrices (mirrors rust data::bitmap)."""
    t = len(transactions)
    tx = np.zeros((t_pad, n_items), dtype=np.float32)
    mask = np.zeros((t_pad, 1), dtype=np.float32)
    for r, items in enumerate(transactions):
        mask[r, 0] = 1.0
        for it in items:
            tx[r, it] = 1.0
    cand = np.zeros((len(candidates), n_items), dtype=np.float32)
    sizes = np.zeros((1, len(candidates)), dtype=np.float32)
    for r, items in enumerate(candidates):
        sizes[0, r] = len(set(items))
        for it in items:
            cand[r, it] = 1.0
    return tx, mask, cand, sizes


def random_db(rng, n_tx, n_items, max_len, n_cand, max_cand_len):
    transactions = [
        set(rng.choice(n_items, size=rng.integers(0, max_len + 1), replace=False))
        for _ in range(n_tx)
    ]
    candidates = [
        sorted(rng.choice(n_items, size=rng.integers(1, max_cand_len + 1), replace=False))
        for _ in range(n_cand)
    ]
    return transactions, candidates


def run_both(transactions, candidates, n_items, t_pad, tile_t):
    tx, mask, cand, sizes = encode_bitmaps(transactions, candidates, n_items, t_pad)
    got = np.asarray(support_count(tx, mask, cand, sizes, tile_t=tile_t))
    ref = np.asarray(support_count_ref(tx, mask, cand, sizes))
    oracle = support_count_py(transactions, candidates)
    return got, ref, np.asarray(oracle, dtype=np.float32).reshape(1, -1)


class TestKernelVsOracles:
    def test_tiny_handchecked(self):
        # db: {0,1,2}, {0,2}, {1}; candidates {0}, {0,2}, {1,2}, {3}
        tr = [{0, 1, 2}, {0, 2}, {1}]
        ca = [[0], [0, 2], [1, 2], [3]]
        got, ref, oracle = run_both(tr, ca, n_items=4, t_pad=4, tile_t=2)
        np.testing.assert_array_equal(oracle, [[2.0, 2.0, 1.0, 0.0]])
        np.testing.assert_array_equal(got, oracle)
        np.testing.assert_array_equal(ref, oracle)

    def test_multi_tile_accumulation(self):
        rng = np.random.default_rng(7)
        tr, ca = random_db(rng, n_tx=100, n_items=32, max_len=12, n_cand=20, max_cand_len=3)
        got, ref, oracle = run_both(tr, ca, n_items=32, t_pad=128, tile_t=32)
        np.testing.assert_array_equal(got, oracle)
        np.testing.assert_array_equal(ref, oracle)

    def test_single_tile(self):
        rng = np.random.default_rng(11)
        tr, ca = random_db(rng, 16, 16, 8, 8, 2)
        got, ref, oracle = run_both(tr, ca, 16, t_pad=16, tile_t=16)
        np.testing.assert_array_equal(got, oracle)

    def test_empty_transactions_all_masked(self):
        ca = [[0], [1, 2]]
        got, ref, oracle = run_both([], ca, n_items=4, t_pad=8, tile_t=4)
        np.testing.assert_array_equal(got, [[0.0, 0.0]])
        np.testing.assert_array_equal(ref, [[0.0, 0.0]])

    def test_empty_transaction_rows(self):
        # Empty transactions contain no non-empty candidate.
        tr = [set(), set(), {1}]
        ca = [[1], [0, 1]]
        got, _, oracle = run_both(tr, ca, n_items=4, t_pad=4, tile_t=4)
        np.testing.assert_array_equal(got, [[1.0, 0.0]])
        np.testing.assert_array_equal(got, oracle)

    def test_duplicate_candidates_counted_independently(self):
        tr = [{0, 1}, {0}]
        ca = [[0], [0], [0, 1]]
        got, _, oracle = run_both(tr, ca, n_items=2, t_pad=2, tile_t=2)
        np.testing.assert_array_equal(got, [[2.0, 2.0, 1.0]])

    def test_full_width_candidate(self):
        n = 8
        tr = [set(range(n)), set(range(n - 1))]
        ca = [list(range(n))]
        got, _, oracle = run_both(tr, ca, n_items=n, t_pad=2, tile_t=2)
        np.testing.assert_array_equal(got, [[1.0]])

    def test_mask_excludes_padding_false_positives(self):
        # A zero pad row would "contain" a size-0 candidate; ensure the
        # mask kills padding rows even in that degenerate case.
        tr = [{0}]
        ca = [[0]]
        tx, mask, cand, sizes = encode_bitmaps(tr, ca, 4, t_pad=64)
        # Deliberately poison padding rows with item bits, mask must win.
        tx[1:, :] = 1.0
        got = np.asarray(support_count(tx, mask, cand, sizes, tile_t=32))
        np.testing.assert_array_equal(got, [[1.0]])

    def test_counts_exact_at_scale(self):
        rng = np.random.default_rng(3)
        tr, ca = random_db(rng, 500, 64, 20, 64, 4)
        got, ref, oracle = run_both(tr, ca, 64, t_pad=512, tile_t=128)
        np.testing.assert_array_equal(got, oracle)
        np.testing.assert_array_equal(ref, oracle)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_tx=st.integers(0, 64),
    n_items=st.sampled_from([8, 16, 32]),
    tile_t=st.sampled_from([8, 16, 32]),
    n_cand=st.integers(1, 24),
)
def test_hypothesis_kernel_matches_python_oracle(seed, n_tx, n_items, tile_t, n_cand):
    """Property: for any random db/candidate set and any tiling, the pallas
    kernel, the jnp oracle and the python set oracle agree exactly."""
    rng = np.random.default_rng(seed)
    tr, ca = random_db(rng, n_tx, n_items, max_len=n_items // 2, n_cand=n_cand,
                       max_cand_len=min(4, n_items))
    t_pad = max(tile_t, ((n_tx + tile_t - 1) // tile_t) * tile_t)
    got, ref, oracle = run_both(tr, ca, n_items, t_pad, tile_t)
    np.testing.assert_array_equal(got, oracle)
    np.testing.assert_array_equal(ref, oracle)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from(["float32", "float64", "int32"]))
def test_hypothesis_input_dtypes_coerce_or_match(dtype):
    """The kernel contract is f32; other integer-valued dtypes must produce
    the same counts after explicit cast (what the rust encoder guarantees)."""
    rng = np.random.default_rng(0)
    tr, ca = random_db(rng, 32, 16, 8, 8, 3)
    tx, mask, cand, sizes = encode_bitmaps(tr, ca, 16, 32)
    cast = lambda a: a.astype(np.float32)  # rust always ships f32
    got = np.asarray(
        support_count(
            cast(tx.astype(dtype)), cast(mask.astype(dtype)),
            cast(cand.astype(dtype)), cast(sizes.astype(dtype)), tile_t=16,
        )
    )
    oracle = np.asarray(support_count_py(tr, ca), dtype=np.float32).reshape(1, -1)
    np.testing.assert_array_equal(got, oracle)


class TestShapeValidation:
    def test_item_width_mismatch_raises(self):
        tx = np.zeros((8, 16), np.float32)
        mask = np.ones((8, 1), np.float32)
        cand = np.zeros((2, 8), np.float32)
        sizes = np.ones((1, 2), np.float32)
        with pytest.raises(ValueError, match="item-width mismatch"):
            support_count(tx, mask, cand, sizes, tile_t=8)

    def test_non_multiple_tile_raises(self):
        tx = np.zeros((10, 16), np.float32)
        mask = np.ones((10, 1), np.float32)
        cand = np.zeros((2, 16), np.float32)
        sizes = np.ones((1, 2), np.float32)
        with pytest.raises(ValueError, match="not a multiple"):
            support_count(tx, mask, cand, sizes, tile_t=8)

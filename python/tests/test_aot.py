"""AOT bridge tests: lowering produces loadable HLO text, the manifest is
consistent, and the interpret-mode pallas lowering contains no Mosaic
custom-call (which the rust CPU-PJRT client could not execute)."""

from __future__ import annotations

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out))
    return out, manifest


def test_manifest_lists_all_modules(built):
    out, manifest = built
    assert manifest["format"] == 1
    names = {(m["graph"], m["variant"]) for m in manifest["modules"]}
    assert ("count_split", "small") in names
    assert ("count_split", "medium") in names
    assert ("count_split", "large") in names
    assert ("count_split_ref", "small") in names
    for m in manifest["modules"]:
        assert os.path.exists(os.path.join(out, m["path"]))
        assert m["bytes"] > 0


def test_hlo_text_parses_as_entry_computation(built):
    out, manifest = built
    for m in manifest["modules"]:
        text = open(os.path.join(out, m["path"])).read()
        assert text.startswith("HloModule"), m["path"]
        assert "ENTRY" in text, m["path"]


def test_no_mosaic_custom_call(built):
    """interpret=True must lower pallas to plain HLO — a tpu_custom_call
    would make the artifact unloadable on the rust CPU client."""
    out, manifest = built
    for m in manifest["modules"]:
        text = open(os.path.join(out, m["path"])).read()
        assert "tpu_custom_call" not in text, m["path"]
        assert "mosaic" not in text.lower(), m["path"]


def test_variant_shapes_appear_in_hlo(built):
    out, manifest = built
    for m in manifest["modules"]:
        text = open(os.path.join(out, m["path"])).read()
        # The tx parameter shape f32[t,i] must appear verbatim.
        assert f"f32[{m['t']},{m['i']}]" in text, m["path"]


def test_sha256_matches_content(built):
    import hashlib

    out, manifest = built
    for m in manifest["modules"]:
        text = open(os.path.join(out, m["path"])).read()
        assert hashlib.sha256(text.encode()).hexdigest() == m["sha256"]


def test_pallas_and_ref_artifacts_agree_when_executed(built):
    """Execute both lowered graphs via jax on the same inputs — the compiled
    artifacts the rust side loads must be numerically identical."""
    rng = np.random.default_rng(5)
    t, i, c = 256, 64, 64
    tx = (rng.random((t, i)) < 0.2).astype(np.float32)
    mask = (rng.random((t, 1)) < 0.9).astype(np.float32)
    cand = (rng.random((c, i)) < 0.05).astype(np.float32)
    sizes = cand.sum(axis=1, keepdims=True).T.astype(np.float32)
    a = jax.jit(model.count_split)(tx, mask, cand, sizes)[0]
    b = jax.jit(model.count_split_ref)(tx, mask, cand, sizes)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_example_args_shapes():
    args = model.example_args(128, 32, 16)
    assert args[0].shape == (128, 32)
    assert args[1].shape == (128, 1)
    assert args[2].shape == (16, 32)
    assert args[3].shape == (1, 16)
